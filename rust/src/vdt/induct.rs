//! Inductive extension — the paper's stated future direction ("adding an
//! inductive feature to our framework to deal with new examples").
//!
//! A fitted [`VdtModel`] is transductive: Q is defined over the N training
//! points. For an unseen query x we derive its outgoing transition row the
//! same way the training rows get theirs, *without* rebuilding anything:
//!
//! 1. Route x down the partition tree by nearest-centroid descent; the
//!    visited path plays the role of the leaf-to-root path a training
//!    point would have (so x inherits a block structure B(x)).
//! 2. For every mark (A, B) on that path, give x the block's kernel-node
//!    target B with the softmax weight of the same variational form used
//!    by the optimizer: G_xB = −D_xB/(2σ²|B|), where
//!    D_xB = Σ_{m∈B} d(x ‖ m) is evaluated in O(d) from the kernel-side
//!    node statistics of the tree's Bregman divergence (under squared
//!    Euclidean: |B|·xᵀx + S2(B) − 2·xᵀS1(B), the Eq. 9 factorization
//!    specialized to a single data point).
//! 3. Normalize over the path with the same hierarchical-softmax
//!    recursion: the per-row partition function reuses the training-time
//!    log Z of the subtrees *below* the path nodes... which for a single
//!    external row degenerates to a flat softmax over B(x) because x
//!    contributes no nested constraints — exactly Eq. (3) restricted to
//!    block-averaged targets.
//!
//! The result is a distribution over tree nodes; [`InductiveRow::expand`]
//! pushes it to the N points (uniform within a kernel block, consistent
//! with the block-sharing semantics), and [`predict_labels`] uses it for
//! out-of-sample label prediction — inductive SSL on top of a fitted
//! transductive model.

use crate::core::error::VdtError;
use crate::core::vecmath::logsumexp;
use crate::core::Matrix;
use crate::tree::PartitionTree;

use super::model::VdtModel;

/// Sparse outgoing transition row of an unseen point: kernel tree nodes
/// with probabilities (summing to 1).
#[derive(Clone, Debug)]
pub struct InductiveRow {
    /// (kernel node, probability mass assigned to the whole block).
    pub targets: Vec<(u32, f64)>,
}

impl InductiveRow {
    /// Expand to a dense length-N row (mass uniform within each block).
    pub fn expand(&self, tree: &PartitionTree) -> Vec<f32> {
        let mut row = vec![0f32; tree.n];
        self.expand_into(tree, &mut row);
        row
    }

    /// Expand into a caller-owned length-N buffer (fully overwritten) —
    /// the allocation-free variant serving request loops reuse.
    pub fn expand_into(&self, tree: &PartitionTree, row: &mut [f32]) {
        assert_eq!(row.len(), tree.n, "inductive row buffer must have length N");
        row.fill(0.0);
        for &(node, mass) in &self.targets {
            let leaves = tree.leaves_under(node);
            let per = (mass / leaves.len() as f64) as f32;
            for &leaf in &leaves {
                row[leaf as usize] += per;
            }
        }
    }

    /// Expected value of per-point scores under this row: Σ_j p_xj y_j —
    /// computed in O(|targets|) from per-node sums (CollectUp-style),
    /// without expanding.
    pub fn score(&self, tree: &PartitionTree, y: &Matrix) -> Vec<f64> {
        let c = y.cols;
        // per-node column sums for just the touched nodes
        let mut out = vec![0f64; c];
        for &(node, mass) in &self.targets {
            let leaves = tree.leaves_under(node);
            let inv = mass / leaves.len() as f64;
            for &leaf in &leaves {
                for k in 0..c {
                    out[k] += inv * y.get(leaf as usize, k) as f64;
                }
            }
        }
        out
    }
}

/// `D_xB = Σ_{m∈B} d(x ‖ m)` — Eq. (9) with A = {x}, evaluated under the
/// tree's divergence from the kernel-side node statistics (under squared
/// Euclidean this is the seed's `|B|·xᵀx + S2(B) − 2·xᵀS1(B)`).
fn d2_point_block(tree: &PartitionTree, x: &[f32], node: u32) -> f64 {
    tree.div.point_block(x, &tree.stats_of(node))
}

/// Route `x` root→leaf by nearest-centroid descent (the mean is the
/// correct Bregman representative for every divergence); returns the path
/// (root first, leaf last).
pub fn route(tree: &PartitionTree, x: &[f32]) -> Vec<u32> {
    let mut path = Vec::with_capacity(32);
    let mut node = tree.root();
    loop {
        path.push(node);
        if tree.is_leaf(node) {
            break;
        }
        let (l, r) = (tree.left[node as usize], tree.right[node as usize]);
        let dl = tree.div.point_to_centroid(x, tree.s1_of(l), tree.count[l as usize] as f64);
        let dr = tree.div.point_to_centroid(x, tree.s1_of(r), tree.count[r as usize] as f64);
        node = if dl <= dr { l } else { r };
    }
    path
}

/// Outgoing transition row of an unseen `x` under a fitted model.
///
/// Library convenience that panics on caller errors; the serving path
/// ([`try_inductive_row`], surfaced as
/// [`crate::core::op::TransitionOp::inductive_into`]) reports the same
/// conditions as typed [`VdtError`]s instead.
pub fn inductive_row(model: &VdtModel, x: &[f32]) -> InductiveRow {
    match try_inductive_row(model, x) {
        Ok(row) => row,
        Err(VdtError::ShapeMismatch { expected, got, .. }) => {
            panic!("query dimension mismatch: expected {expected}, got {got}")
        }
        Err(VdtError::Domain { divergence, reason, .. }) => {
            panic!("query outside the {divergence} domain: {reason}")
        }
        Err(e) => panic!("{e}"),
    }
}

/// Fallible [`inductive_row`]: a wrong-dimension query is
/// [`VdtError::ShapeMismatch`] and an out-of-domain query (NaN, or e.g. a
/// near-zero coordinate under Itakura-Saito) is [`VdtError::Domain`] with
/// `row = 0` — callers batching several queries remap the row index.
pub fn try_inductive_row(model: &VdtModel, x: &[f32]) -> Result<InductiveRow, VdtError> {
    let tree = &model.tree;
    if x.len() != tree.d {
        return Err(VdtError::ShapeMismatch { what: "query", expected: tree.d, got: x.len() });
    }
    // same fail-fast domain gate as build_tree_impl: a NaN (or, under
    // Itakura-Saito, a near-zero coordinate) would otherwise flow through
    // route()/d2_point_block and come back as a silently garbage posterior
    if let Err(reason) = tree.div.check_point(x) {
        return Err(VdtError::Domain { divergence: tree.div.name(), row: 0, reason });
    }
    let sigma = model.sigma();
    let path = route(tree, x);
    // collect the marks along the adopted path (x behaves like a point in
    // the leaf it routed to)
    let mut kernels: Vec<u32> = Vec::new();
    for &a in &path {
        for &bi in &model.partition.marks[a as usize] {
            kernels.push(model.partition.blocks[bi as usize].kernel);
        }
    }
    if kernels.is_empty() {
        // degenerate single-point model
        return Ok(InductiveRow { targets: vec![] });
    }
    // flat softmax over the path blocks with block-averaged energies:
    // weight(B) ∝ |B| · exp(−D²_xB / (2σ²|B|))   (mass for the whole block)
    let logits: Vec<f64> = kernels
        .iter()
        .map(|&b| {
            let nb = tree.count[b as usize] as f64;
            let g = -d2_point_block(tree, x, b) / (2.0 * sigma * sigma * nb);
            nb.ln() + g
        })
        .collect();
    let z = logsumexp(&logits);
    let targets = kernels
        .into_iter()
        .zip(logits)
        .map(|(b, l)| (b, (l - z).exp()))
        .collect();
    Ok(InductiveRow { targets })
}

/// Inductive label prediction: score each class by the expected label
/// value under the query's transition row; returns (class, scores).
pub fn predict_label(model: &VdtModel, x: &[f32], y: &Matrix) -> (usize, Vec<f64>) {
    let row = inductive_row(model, x);
    let scores = row.score(&model.tree, y);
    let mut best = 0;
    for (k, &s) in scores.iter().enumerate() {
        if s > scores[best] {
            best = k;
        }
    }
    (best, scores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::labelprop;
    use crate::vdt::{VdtConfig, VdtModel};

    fn fitted(n: usize, seed: u64) -> (crate::data::Dataset, VdtModel) {
        let ds = synthetic::two_moons(n, 0.07, seed);
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        m.refine_to(6 * n);
        (ds, m)
    }

    #[test]
    fn row_is_a_distribution() {
        let (ds, m) = fitted(120, 1);
        for i in (0..ds.n()).step_by(17) {
            let row = inductive_row(&m, ds.x.row(i));
            let expanded = row.expand(&m.tree);
            let sum: f64 = expanded.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-5, "query {i}: sum {sum}");
            assert!(expanded.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn route_reaches_a_leaf_near_the_query() {
        let (ds, m) = fitted(100, 2);
        for i in (0..100).step_by(13) {
            let path = route(&m.tree, ds.x.row(i));
            let leaf = *path.last().unwrap();
            assert!(m.tree.is_leaf(leaf));
            // the routed leaf should be close (not necessarily identical —
            // centroid descent is greedy): within the 25th percentile of
            // distances to the query
            let d_leaf =
                crate::core::vecmath::sq_dist(ds.x.row(i), ds.x.row(leaf as usize));
            let mut dists: Vec<f64> = (0..100)
                .map(|j| crate::core::vecmath::sq_dist(ds.x.row(i), ds.x.row(j)))
                .collect();
            dists.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            assert!(d_leaf <= dists[25], "routed leaf too far: {d_leaf}");
        }
    }

    #[test]
    fn inductive_prediction_matches_labels_on_held_out_moons() {
        // train on 300, predict 100 held-out points inductively
        let train = synthetic::two_moons(300, 0.07, 3);
        let test = synthetic::two_moons(100, 0.07, 99);
        let mut m = VdtModel::build(&train.x, &VdtConfig::default());
        m.refine_to(8 * train.n());
        // propagate labels transductively first
        let labeled = labelprop::choose_labeled(&train.labels, 2, 20, 4);
        let (y, _) = labelprop::run_ssl(
            &m,
            &train.labels,
            2,
            &labeled,
            &labelprop::LpConfig { alpha: 0.5, steps: 100 },
        );
        // then predict held-out points from the propagated scores
        let mut correct = 0;
        for i in 0..test.n() {
            let (pred, _) = predict_label(&m, test.x.row(i), &y);
            if pred == test.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.n() as f64;
        assert!(acc > 0.85, "inductive accuracy {acc}");
    }

    #[test]
    fn score_agrees_with_expanded_row() {
        let (ds, m) = fitted(60, 5);
        let y = labelprop::one_hot_labels(&ds.labels, 2);
        let row = inductive_row(&m, ds.x.row(7));
        let fast = row.score(&m.tree, &y);
        let expanded = row.expand(&m.tree);
        for k in 0..2 {
            let want: f64 = expanded
                .iter()
                .enumerate()
                .map(|(j, &p)| p as f64 * y.get(j, k) as f64)
                .sum();
            // expand() rounds per-leaf mass to f32; score() stays f64
            assert!((fast[k] - want).abs() < 1e-5, "class {k}: {} vs {want}", fast[k]);
        }
    }

    #[test]
    fn try_inductive_row_reports_typed_errors() {
        let (ds, m) = fitted(40, 8);
        // happy path agrees with the panicking wrapper
        let a = try_inductive_row(&m, ds.x.row(3)).unwrap();
        let b = inductive_row(&m, ds.x.row(3));
        assert_eq!(a.targets, b.targets);
        // wrong dimension is a typed shape mismatch
        let err = try_inductive_row(&m, &[0.0; 5]).unwrap_err();
        assert!(
            matches!(err, VdtError::ShapeMismatch { expected: 2, got: 5, .. }),
            "{err}"
        );
        // out-of-domain query is a typed domain error
        let err = try_inductive_row(&m, &[f32::NAN, 0.0]).unwrap_err();
        assert!(
            matches!(err, VdtError::Domain { divergence: "sq_euclidean", .. }),
            "{err}"
        );
    }

    #[test]
    fn expand_into_overwrites_dirty_buffers() {
        let (ds, m) = fitted(30, 9);
        let row = inductive_row(&m, ds.x.row(4));
        let want = row.expand(&m.tree);
        let mut dirty = vec![7.5f32; 30];
        row.expand_into(&m.tree, &mut dirty);
        assert_eq!(dirty, want);
    }

    #[test]
    #[should_panic]
    fn dimension_mismatch_panics() {
        let (_, m) = fitted(30, 6);
        let _ = inductive_row(&m, &[0.0; 5]);
    }

    #[test]
    #[should_panic(expected = "outside the sq_euclidean domain")]
    fn out_of_domain_query_panics() {
        let (_, m) = fitted(30, 7);
        let _ = inductive_row(&m, &[f32::NAN, 0.0]);
    }
}
