//! Greedy symmetric refinement (paper §4.4).
//!
//! Horizontal refinement splits a block (A, B) into {(A, B_l), (A, B_r)}.
//! Keeping all other q fixed, the row constraints force
//! `|B_l|q_l + |B_r|q_r = |B|q` (Eq. 17), whose constrained optimum is the
//! local softmax of Eq. (18); the resulting bound improvement is the
//! closed-form gain Δʰ_AB of Eq. (19) — a *lower bound* on the true gain
//! (a later global re-optimization can only help, by the Eq. 6 argument).
//!
//! Vertical refinements admit no such local bound, so the paper refines
//! *symmetrically*: popping (A, B) also horizontally refines its mirror
//! (B, A) when that block is present, which plays the role of the vertical
//! split of (A, B).
//!
//! The refiner keeps a max-heap of candidate gains with lazy invalidation
//! (entries are stamped with the block's index; dead blocks are skipped on
//! pop). Blocks whose kernel node is a leaf cannot be split horizontally
//! and never enter the heap.

use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::core::par;
use crate::core::vecmath::logsumexp;
use crate::tree::PartitionTree;

use super::optimize::{g_of, optimize_q, OptScratch};
use super::partition::BlockPartition;

/// Below this block count, candidate scoring stays serial.
const PAR_MIN_BLOCKS: usize = 4096;

/// Score every block's horizontal gain (`None` = not refinable) — the
/// candidate-generation pass feeding the greedy heap. Scoring is
/// independent per block and fans out on [`crate::core::par`]; results
/// come back in block order, so the heap the caller builds is identical
/// to the serial path's.
fn score_gains(
    tree: &PartitionTree,
    part: &BlockPartition,
    sigma: f64,
) -> Vec<Option<f64>> {
    let nblocks = part.blocks.len();
    let score = |i: usize| {
        if part.blocks[i].alive {
            gain_h(tree, part, i as u32, sigma)
        } else {
            None
        }
    };
    if par::is_parallel() && nblocks >= PAR_MIN_BLOCKS {
        par::par_map(nblocks, score)
    } else {
        (0..nblocks).map(score).collect()
    }
}

/// Max-heap entry ordered by gain.
struct Candidate {
    gain: f64,
    block: u32,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.gain == other.gain && self.block == other.block
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain
            .partial_cmp(&other.gain)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.block.cmp(&other.block))
    }
}

/// Greedy refinement driver. Owns the candidate heap and the (data,
/// kernel) → block index map used to find symmetric counterparts.
pub struct Refiner {
    heap: BinaryHeap<Candidate>,
    index: HashMap<(u32, u32), u32>,
    sigma: f64,
    /// Re-run the global optimizer whenever |B| has grown by this factor
    /// since the last re-optimization (1.1 = every 10% growth). The paper
    /// re-optimizes after refinement; doing it on a growth schedule keeps
    /// the amortized cost at O(|B| log |B|) per level (Table 1).
    pub reopt_growth: f64,
    last_opt_size: usize,
    scratch: OptScratch,
}

impl Refiner {
    /// Build a refiner for the current partition (q must be optimized).
    pub fn new(tree: &PartitionTree, part: &BlockPartition, sigma: f64) -> Refiner {
        let mut r = Refiner {
            heap: BinaryHeap::new(),
            index: HashMap::with_capacity(part.num_blocks() * 2),
            sigma,
            reopt_growth: 1.1,
            last_opt_size: part.num_blocks(),
            scratch: OptScratch::default(),
        };
        for (i, b) in part.alive_blocks() {
            r.index.insert((b.data, b.kernel), i);
        }
        for (i, gain) in score_gains(tree, part, sigma).into_iter().enumerate() {
            if let Some(gain) = gain {
                r.heap.push(Candidate { gain, block: i as u32 });
            }
        }
        r
    }

    /// Refine until `part.num_blocks() >= target` (or no refinable blocks
    /// remain). Returns the number of split operations performed.
    pub fn refine_to(
        &mut self,
        tree: &PartitionTree,
        part: &mut BlockPartition,
        target: usize,
    ) -> usize {
        let mut splits = 0;
        while part.num_blocks() < target {
            let cand = match self.heap.pop() {
                Some(c) => c,
                None => break,
            };
            let blk = &part.blocks[cand.block as usize];
            if !blk.alive {
                continue; // stale heap entry
            }
            let (a, b) = (blk.data, blk.kernel);
            self.split(tree, part, cand.block);
            splits += 1;
            // symmetric counterpart (B, A): the stand-in for the vertical
            // refinement of (A, B)
            if part.num_blocks() < target {
                if let Some(&mirror) = self.index.get(&(b, a)) {
                    if part.blocks[mirror as usize].alive && !tree.is_leaf(a) {
                        self.split(tree, part, mirror);
                        splits += 1;
                    }
                }
            }
            // periodic global re-optimization: recompute all q at the
            // current partition and rebuild gains
            if part.num_blocks() as f64 >= self.last_opt_size as f64 * self.reopt_growth {
                self.reoptimize(tree, part);
            }
        }
        self.reoptimize(tree, part);
        splits
    }

    /// Globally re-optimize q and rebuild the gain heap (candidate scoring
    /// fans out per block; see [`score_gains`]).
    pub fn reoptimize(&mut self, tree: &PartitionTree, part: &mut BlockPartition) {
        optimize_q(tree, part, self.sigma, &mut self.scratch);
        self.last_opt_size = part.num_blocks();
        self.heap.clear();
        for (i, gain) in score_gains(tree, part, self.sigma).into_iter().enumerate() {
            if let Some(gain) = gain {
                self.heap.push(Candidate { gain, block: i as u32 });
            }
        }
    }

    /// Horizontally split block `bi` = (A, B) into (A, B_l), (A, B_r) with
    /// the locally-optimal q of Eq. (18).
    fn split(&mut self, tree: &PartitionTree, part: &mut BlockPartition, bi: u32) {
        let (a, b) = {
            let blk = &part.blocks[bi as usize];
            (blk.data, blk.kernel)
        };
        let (il, ir) = split_block(tree, part, bi, self.sigma);
        let (bl, br) = (tree.left[b as usize], tree.right[b as usize]);
        self.index.remove(&(a, b));
        self.index.insert((a, bl), il);
        self.index.insert((a, br), ir);
        for i in [il, ir] {
            if let Some(gain) = gain_h(tree, part, i, self.sigma) {
                self.heap.push(Candidate { gain, block: i });
            }
        }
    }
}

/// Horizontally split block `bi` = (A, B) into (A, B_l), (A, B_r) with the
/// locally-optimal q reallocation of Eq. (18), returning the two child
/// block indices `(left, right)`. This is the raw partition operation the
/// [`Refiner`] wraps with its heap/index bookkeeping; the online-ingest
/// path ([`crate::vdt::ingest`]) calls it directly for threshold-triggered
/// local re-refinement. The kernel node of `bi` must not be a leaf.
pub(crate) fn split_block(
    tree: &PartitionTree,
    part: &mut BlockPartition,
    bi: u32,
    sigma: f64,
) -> (u32, u32) {
    let blk = part.blocks[bi as usize].clone();
    debug_assert!(blk.alive && !tree.is_leaf(blk.kernel));
    let (a, b) = (blk.data, blk.kernel);
    let (bl, br) = (tree.left[b as usize], tree.right[b as usize]);
    let d2l = tree.d2_between(a, bl);
    let d2r = tree.d2_between(a, br);
    let (nb, nbl, nbr) = (
        tree.count[b as usize] as f64,
        tree.count[bl as usize] as f64,
        tree.count[br as usize] as f64,
    );
    let gl = g_of(tree, a, bl, d2l, sigma);
    let gr = g_of(tree, a, br, d2r, sigma);
    // Eq. (18) in log space: q_c = |B| e^{G_c} q / Σ_t |B_t| e^{G_t}
    let log_den = logsumexp(&[nbl.ln() + gl, nbr.ln() + gr]);
    let (ql, qr) = if blk.q > 0.0 {
        (
            (nb.ln() + gl + blk.q.ln() - log_den).exp(),
            (nb.ln() + gr + blk.q.ln() - log_den).exp(),
        )
    } else {
        (0.0, 0.0)
    };

    part.kill_block(bi);
    let il = part.push_block(a, bl, d2l);
    part.blocks[il as usize].q = ql;
    let ir = part.push_block(a, br, d2r);
    part.blocks[ir as usize].q = qr;
    (il, ir)
}

/// Δʰ_AB of Eq. (19); `None` when B is a leaf (not horizontally
/// refinable). Always ≥ 0 for q > 0 (Jensen).
pub fn gain_h(
    tree: &PartitionTree,
    part: &BlockPartition,
    block: u32,
    sigma: f64,
) -> Option<f64> {
    let b = &part.blocks[block as usize];
    if tree.is_leaf(b.kernel) {
        return None;
    }
    if b.q <= 0.0 {
        return Some(0.0);
    }
    let (bl, br) = (tree.left[b.kernel as usize], tree.right[b.kernel as usize]);
    let na = tree.count[b.data as usize] as f64;
    let nb = tree.count[b.kernel as usize] as f64;
    let (nbl, nbr) = (tree.count[bl as usize] as f64, tree.count[br as usize] as f64);
    let g = g_of(tree, b.data, b.kernel, b.d2, sigma);
    let gl = g_of(tree, b.data, bl, tree.d2_between(b.data, bl), sigma);
    let gr = g_of(tree, b.data, br, tree.d2_between(b.data, br), sigma);
    let log_num = logsumexp(&[nbl.ln() + gl, nbr.ln() + gr]);
    Some((na * nb * b.q * (log_num - nb.ln() - g)).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tree::{build_tree, BuildConfig};
    use crate::vdt::optimize::loglik;
    use crate::vdt::sigma::fit_alternating;

    fn setup(n: usize, seed: u64) -> (PartitionTree, BlockPartition, f64) {
        let ds = synthetic::gaussian_mixture(n, 3, 2, 2, 2.0, seed, "t");
        let t = build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        let mut p = BlockPartition::coarsest(&t);
        let r = fit_alternating(&t, &mut p, None, 1e-8, 100);
        let s = r.sigma;
        (t, p, s)
    }

    #[test]
    fn refinement_grows_partition_and_stays_valid() {
        let (t, mut p, s) = setup(24, 1);
        let mut refiner = Refiner::new(&t, &p, s);
        let start = p.num_blocks();
        refiner.refine_to(&t, &mut p, 4 * 24);
        assert!(p.num_blocks() >= 4 * 24, "got {}", p.num_blocks());
        assert!(p.num_blocks() > start);
        p.validate(&t).unwrap();
    }

    #[test]
    fn loglik_never_decreases_along_refinement_path() {
        let (t, mut p, s) = setup(20, 3);
        let mut prev = loglik(&t, &p, s);
        let mut refiner = Refiner::new(&t, &p, s);
        for level in 2..7usize {
            refiner.refine_to(&t, &mut p, level * 20);
            let cur = loglik(&t, &p, s);
            assert!(cur >= prev - 1e-6, "level {level}: ℓ {cur} < {prev}");
            prev = cur;
        }
    }

    #[test]
    fn gain_formula_matches_local_delta() {
        // Apply one split WITHOUT global re-opt; ℓ' − ℓ must equal Δʰ.
        let (t, mut p, s) = setup(16, 5);
        let before = loglik(&t, &p, s);
        // best refinable block
        let (bi, gain) = p
            .alive_blocks()
            .filter_map(|(i, _)| gain_h(&t, &p, i, s).map(|g| (i, g)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let mut refiner = Refiner::new(&t, &p, s);
        refiner.split(&t, &mut p, bi);
        let after = loglik(&t, &p, s);
        let actual = after - before;
        assert!(
            (actual - gain).abs() < 1e-6 * (1.0 + gain.abs()),
            "Δ formula {gain} vs actual {actual}"
        );
    }

    #[test]
    fn split_preserves_row_sums_locally() {
        // Eq. (17): splitting without re-opt keeps Q row-stochastic.
        let (t, mut p, s) = setup(14, 7);
        let mut refiner = Refiner::new(&t, &p, s);
        let bi = p
            .alive_blocks()
            .find(|(_, b)| !t.is_leaf(b.kernel) && b.q > 0.0)
            .map(|(i, _)| i)
            .unwrap();
        refiner.split(&t, &mut p, bi);
        let q = p.materialize(&t);
        for (i, sum) in q.row_sums().iter().enumerate() {
            assert!((sum - 1.0).abs() < 1e-5, "row {i} sum {sum}");
        }
        p.validate(&t).unwrap();
    }

    #[test]
    fn refinement_stalls_only_at_leaf_kernels() {
        // With an unbounded target, greedy symmetric refinement exhausts
        // every horizontally-splittable block. The paper's scheme cannot
        // split a block whose *kernel* node is a leaf (that would need a
        // true vertical refinement, §4.4), so at the stall point every
        // alive block has a leaf kernel, the partition is still valid, and
        // Q is still row-stochastic.
        let (t, mut p, s) = setup(8, 9);
        let mut refiner = Refiner::new(&t, &p, s);
        refiner.refine_to(&t, &mut p, usize::MAX / 2);
        p.validate(&t).unwrap();
        for (_, b) in p.alive_blocks() {
            assert!(t.is_leaf(b.kernel), "block ({},{}) still splittable", b.data, b.kernel);
        }
        assert!(p.num_blocks() > 2 * (8 - 1), "no refinement happened");
        let q = p.materialize(&t);
        for s in q.row_sums() {
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn heap_gains_are_nonnegative() {
        let (t, p, s) = setup(18, 11);
        for (i, _) in p.alive_blocks() {
            if let Some(g) = gain_h(&t, &p, i, s) {
                assert!(g >= 0.0, "negative gain {g}");
            }
        }
    }
}
