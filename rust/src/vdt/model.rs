//! [`VdtModel`] — the user-facing Variational Dual-Tree model.
//!
//! `build` = anchor tree + coarsest partition + alternating (q, σ) fit:
//! `O(N^1.5 log N + |B|)` construction, `O(|B|)` memory (Table 1).
//! `refine_to` grows |B| greedily (paper §4.4); `matvec` is Algorithm 1.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::core::divergence::{Divergence, DivergenceKind};
use crate::core::error::VdtError;
use crate::core::Matrix;
use crate::core::op::{Backend, ModelCard, TransitionOp};
use crate::runtime::snapshot::{instantiate_divergence, Snapshot};
use crate::tree::{build_tree_with, BuildConfig, PartitionTree, NONE};

use super::matvec::{matmul, matmul_into, MatvecScratch};
use super::optimize::loglik;
use super::partition::{Block, BlockPartition};
use super::refine::Refiner;
use super::sigma::fit_alternating;

/// Configuration for [`VdtModel::build`].
#[derive(Clone, Debug)]
pub struct VdtConfig {
    pub tree: BuildConfig,
    /// Geometry the model is fitted under (see
    /// [`crate::core::divergence`]). The default squared-Euclidean choice
    /// reproduces the paper bit-for-bit; [`VdtModel::build_with`] accepts
    /// an explicit [`Divergence`] instance instead.
    pub divergence: DivergenceKind,
    /// Fixed bandwidth; `None` learns σ by the paper's alternating scheme.
    pub sigma: Option<f64>,
    /// Relative σ convergence tolerance of the alternating fit.
    pub sigma_tol: f64,
    /// Maximum alternating iterations.
    pub sigma_max_iters: usize,
}

impl Default for VdtConfig {
    fn default() -> Self {
        VdtConfig {
            // the VDT model never reads node radii — skip the exact-radius
            // post-pass (it cost ~25-35% of construction at N=16k; §Perf)
            tree: BuildConfig { exact_radii: false, ..BuildConfig::default() },
            divergence: DivergenceKind::SqEuclidean,
            sigma: None,
            sigma_tol: 1e-4,
            sigma_max_iters: 50,
        }
    }
}

/// A fitted variational dual-tree transition model Q ≈ P.
pub struct VdtModel {
    pub tree: PartitionTree,
    pub partition: BlockPartition,
    sigma: f64,
    refiner: Option<Refiner>,
    /// Pool of reusable matvec scratch buffers. A Mutex (not RefCell) so
    /// fitted models are `Sync` and shareable with the coordinator behind
    /// an `Arc`; a *pool* (not a single scratch) so concurrent `&self`
    /// matvecs each pop their own buffers and run truly in parallel —
    /// the lock is held only for the pop/push, never the sweep. Steady
    /// state (e.g. LP iterations) allocates nothing per call.
    scratch_pool: std::sync::Mutex<Vec<MatvecScratch>>,
    /// Dataset the model was fitted on (recorded by the builder / loaded
    /// from a snapshot's meta section), for [`ModelCard::provenance`].
    provenance: Option<String>,
    /// Ingest epoch (0 = fitted from scratch, k+1 = committed on top of an
    /// epoch-k parent; see [`crate::runtime::ingest`]).
    epoch: u64,
    /// FNV-1a checksum of the parent epoch's encoded snapshot (0 iff
    /// `epoch == 0`) — the lineage record snapshot format v2 persists.
    parent_sum: u64,
}

impl VdtModel {
    /// Build the coarsest model (|B| = 2(N−1)) and fit (q, σ) under the
    /// geometry selected by `cfg.divergence`. The default Euclidean kind
    /// takes the monomorphized [`crate::tree::build_tree`] path (inlined
    /// `sq_dist` inner loops, bit-identical to the seed).
    pub fn build(x: &Matrix, cfg: &VdtConfig) -> VdtModel {
        let tree = match &cfg.divergence {
            DivergenceKind::SqEuclidean => crate::tree::build_tree(x, &cfg.tree),
            kind => build_tree_with(x, &cfg.tree, kind.instantiate(x)),
        };
        Self::fit(tree, cfg)
    }

    /// Build under an explicit [`Divergence`] instance — the generic
    /// entry point for custom geometries:
    /// `VdtModel::build_with(&x, &cfg, KlSimplex)`.
    pub fn build_with<D: Divergence + 'static>(x: &Matrix, cfg: &VdtConfig, div: D) -> VdtModel {
        Self::build_with_arc(x, cfg, Arc::new(div))
    }

    /// Build under a shared divergence handle (used by the coordinator
    /// and custom callers holding type-erased geometries).
    pub fn build_with_arc(
        x: &Matrix,
        cfg: &VdtConfig,
        div: Arc<dyn Divergence>,
    ) -> VdtModel {
        Self::fit(build_tree_with(x, &cfg.tree, div), cfg)
    }

    /// Shared fit tail: coarsest partition + alternating (q, σ) on an
    /// already-built tree.
    fn fit(tree: PartitionTree, cfg: &VdtConfig) -> VdtModel {
        let mut partition = BlockPartition::coarsest(&tree);
        let sigma = if let Some(s) = cfg.sigma {
            // fixed bandwidth: single q-optimization, no σ updates
            let mut scratch = super::optimize::OptScratch::default();
            super::optimize::optimize_q(&tree, &mut partition, s, &mut scratch);
            s
        } else {
            fit_alternating(&tree, &mut partition, None, cfg.sigma_tol, cfg.sigma_max_iters)
                .sigma
        };
        VdtModel {
            tree,
            partition,
            sigma,
            refiner: None,
            scratch_pool: std::sync::Mutex::new(Vec::new()),
            provenance: None,
            epoch: 0,
            parent_sum: 0,
        }
    }

    /// Number of variational parameters |B| (off-diagonal blocks).
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.partition.num_blocks()
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.tree.n
    }

    /// Learned (or fixed) kernel bandwidth.
    #[inline]
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Name of the Bregman geometry the model was fitted under.
    #[inline]
    pub fn divergence_name(&self) -> &'static str {
        self.tree.div.name()
    }

    /// Current variational lower bound ℓ(D) (Eq. 7).
    pub fn loglik(&self) -> f64 {
        loglik(&self.tree, &self.partition, self.sigma)
    }

    /// Greedy symmetric refinement to at least `target` blocks; see
    /// [`super::refine`]. Returns the number of split operations.
    pub fn refine_to(&mut self, target: usize) -> usize {
        if self.refiner.is_none() {
            self.refiner = Some(Refiner::new(&self.tree, &self.partition, self.sigma));
        }
        let refiner = self.refiner.as_mut().unwrap();
        refiner.refine_to(&self.tree, &mut self.partition, target)
    }

    /// Pop/push access to the scratch pool that survives a poisoned lock:
    /// the scratch buffers hold no invariants across calls (every sweep
    /// fully re-initializes its lanes), so if a worker thread panicked
    /// while holding the lock we take the inner value rather than wedging
    /// every later matvec behind a `PoisonError`.
    fn pool(&self) -> std::sync::MutexGuard<'_, Vec<MatvecScratch>> {
        self.scratch_pool.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Ŷ = Q·Y via Algorithm 1, O((N+|B|)·C) — the true multi-RHS path:
    /// all C columns of `y` share one flattened pass over the block
    /// partition (see [`super::matvec::matmul_into`]). Thread-safe through
    /// `&self`: each call borrows a scratch from the pool (allocating one
    /// only the first time a new concurrency level is reached) and returns
    /// it after the sweep, so concurrent callers never serialize on the
    /// buffers.
    pub fn matmul(&self, y: &Matrix) -> Matrix {
        let mut scratch = self.pool().pop().unwrap_or_default();
        let out = matmul(&self.tree, &self.partition, y, &mut scratch);
        self.pool().push(scratch);
        out
    }

    /// Multi-RHS Ŷ = Q·Y into a caller-owned buffer (`n × y.cols`, fully
    /// overwritten): the allocation-free serving path — steady state
    /// reuses the pooled scratch lanes *and* the caller's output matrix.
    /// Output is bit-identical to C stacked single-column calls in the
    /// default SIMD tier (see [`crate::core::simd`]).
    pub fn matmul_into(&self, y: &Matrix, out: &mut Matrix) {
        let mut scratch = self.pool().pop().unwrap_or_default();
        matmul_into(&self.tree, &self.partition, y, &mut scratch, out);
        self.pool().push(scratch);
    }

    /// Alias for [`VdtModel::matmul`] (the historical name; multi-column Y
    /// was always accepted).
    pub fn matvec(&self, y: &Matrix) -> Matrix {
        self.matmul(y)
    }

    /// Alias for [`VdtModel::matmul_into`].
    pub fn matvec_into(&self, y: &Matrix, out: &mut Matrix) {
        self.matmul_into(y, out);
    }

    /// Record what the model was fitted on (shown in the
    /// [`ModelCard`]; the builder sets this from the dataset name).
    pub fn set_provenance(&mut self, name: impl Into<String>) {
        self.provenance = Some(name.into());
    }

    /// Dataset provenance, when recorded.
    pub fn provenance(&self) -> Option<&str> {
        self.provenance.as_deref()
    }

    /// Ingest epoch this model serves (0 for a from-scratch fit).
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// FNV-1a checksum of the parent epoch's encoded snapshot; 0 iff
    /// `epoch() == 0`.
    #[inline]
    pub fn parent_sum(&self) -> u64 {
        self.parent_sum
    }

    /// Stamp the epoch lineage on a committed model (see
    /// [`crate::runtime::ingest::EpochLedger::commit`]). `epoch == 0` must
    /// pair with `parent_sum == 0` and vice versa — snapshot v2 rejects
    /// inconsistent lineage at encode *and* decode.
    pub fn set_lineage(&mut self, epoch: u64, parent_sum: u64) {
        self.epoch = epoch;
        self.parent_sum = parent_sum;
    }

    /// Drop derived state (the refiner's gain heap and block index) after
    /// an external structural mutation of the tree/partition — the online
    /// ingest path calls this; `refine_to` rebuilds lazily.
    pub fn invalidate_derived(&mut self) {
        self.refiner = None;
    }

    /// Dense materialization of Q (tests / tiny N).
    pub fn materialize(&self) -> Matrix {
        self.partition.materialize(&self.tree)
    }

    /// Capture the fitted state as a [`Snapshot`] (see
    /// [`crate::runtime::snapshot`]). Dead (refined-away) blocks are
    /// compacted out; per-node mark order is preserved verbatim, so a
    /// loaded model replays matvec / label-propagation f64 accumulation
    /// bit-identically. Derived state (refiner heap, scratch pools) is
    /// deliberately omitted and rebuilt lazily on load.
    pub fn to_snapshot(&self, meta_name: &str) -> Snapshot {
        let t = &self.tree;
        let nb = self.partition.num_blocks();
        let mut remap = vec![u32::MAX; self.partition.blocks.len()];
        let mut blk_data = Vec::with_capacity(nb);
        let mut blk_kernel = Vec::with_capacity(nb);
        let mut blk_q = Vec::with_capacity(nb);
        let mut blk_d2 = Vec::with_capacity(nb);
        for (i, b) in self.partition.blocks.iter().enumerate() {
            if b.alive {
                remap[i] = blk_data.len() as u32;
                blk_data.push(b.data);
                blk_kernel.push(b.kernel);
                blk_q.push(b.q);
                blk_d2.push(b.d2);
            }
        }
        let marks = self
            .partition
            .marks
            .iter()
            .map(|ms| ms.iter().map(|&m| remap[m as usize]).collect())
            .collect();
        Snapshot {
            divergence: t.div.name().to_string(),
            div_params: t.div.snapshot_params(),
            n: t.n,
            d: t.d,
            sigma: self.sigma,
            meta_name: meta_name.to_string(),
            left: t.left.clone(),
            right: t.right.clone(),
            parent: t.parent.clone(),
            count: t.count.clone(),
            s2: t.s2.clone(),
            radius: t.radius.clone(),
            s1: t.s1.clone(),
            sg: t.sg.clone(),
            spsi: t.spsi.clone(),
            blk_data,
            blk_kernel,
            blk_q,
            blk_d2,
            marks,
            epoch: self.epoch,
            parent_sum: self.parent_sum,
        }
    }

    /// Rebuild a fitted model from a decoded [`Snapshot`]: re-instantiate
    /// the divergence from the registry, structurally validate the tree
    /// and partition (fail fast — a corrupt file must never become a
    /// silently-wrong model), and recreate the derived scratch state the
    /// snapshot omits.
    pub fn from_snapshot(s: Snapshot) -> Result<VdtModel> {
        let nn = s.left.len();
        if s.n == 0 || s.d == 0 || nn != 2 * s.n - 1 {
            bail!("snapshot shape invalid: n={}, d={}, {nn} tree nodes", s.n, s.d);
        }
        if s.right.len() != nn
            || s.parent.len() != nn
            || s.count.len() != nn
            || s.s2.len() != nn
            || s.radius.len() != nn
            || s.s1.len() != nn * s.d
            || s.marks.len() != nn
            || s.blk_kernel.len() != s.blk_data.len()
            || s.blk_q.len() != s.blk_data.len()
            || s.blk_d2.len() != s.blk_data.len()
        {
            bail!("snapshot arrays disagree on the model shape");
        }
        if !s.sigma.is_finite() || s.sigma <= 0.0 {
            bail!("snapshot sigma {} is not a positive finite bandwidth", s.sigma);
        }
        let div = instantiate_divergence(&s.divergence, &s.div_params, s.d)?;
        if div.needs_grad_stats() {
            if s.sg.len() != nn * s.d || s.spsi.len() != nn {
                bail!(
                    "snapshot is missing the gradient statistics divergence {} requires",
                    s.divergence
                );
            }
        } else if !s.sg.is_empty() || !s.spsi.is_empty() {
            bail!("snapshot carries gradient statistics divergence {} never reads", s.divergence);
        }

        // tree topology: leaves are 0..n with count 1; internal nodes have
        // two distinct smaller-id children with consistent parent links,
        // each non-root node claimed exactly once (matvec's CollectUp /
        // DistributeDown sweeps index on these invariants)
        let mut claimed = vec![false; nn];
        for a in 0..nn {
            if a < s.n {
                if s.left[a] != NONE || s.right[a] != NONE || s.count[a] != 1 {
                    bail!("snapshot tree: leaf {a} is malformed");
                }
            } else {
                let (l, r) = (s.left[a] as usize, s.right[a] as usize);
                if s.left[a] == NONE || s.right[a] == NONE || l >= a || r >= a || l == r {
                    bail!("snapshot tree: internal node {a} has invalid children");
                }
                if s.parent[l] != a as u32 || s.parent[r] != a as u32 {
                    bail!("snapshot tree: parent links broken at node {a}");
                }
                if claimed[l] || claimed[r] {
                    bail!("snapshot tree: node claimed by two parents under {a}");
                }
                claimed[l] = true;
                claimed[r] = true;
                if s.count[a] as u64 != s.count[l] as u64 + s.count[r] as u64 {
                    bail!("snapshot tree: count mismatch at node {a}");
                }
            }
        }
        if s.parent[nn - 1] != NONE {
            bail!("snapshot tree: root has a parent");
        }
        if s.count[nn - 1] as usize != s.n {
            bail!("snapshot tree: root count {} != n {}", s.count[nn - 1], s.n);
        }

        let mut blocks = Vec::with_capacity(s.blk_data.len());
        for i in 0..s.blk_data.len() {
            let (data, kernel) = (s.blk_data[i], s.blk_kernel[i]);
            if data as usize >= nn || kernel as usize >= nn {
                bail!("snapshot block {i} references nodes ({data},{kernel}) outside the tree");
            }
            let (q, d2) = (s.blk_q[i], s.blk_d2[i]);
            if !q.is_finite() || q < 0.0 || !d2.is_finite() {
                bail!("snapshot block {i} has invalid q={q} / d2={d2}");
            }
            blocks.push(Block { data, kernel, q, d2, alive: true });
        }
        let partition = BlockPartition::from_parts(blocks, s.marks)
            .map_err(|e| anyhow!("snapshot partition invalid: {e}"))?;

        let tree = PartitionTree {
            n: s.n,
            d: s.d,
            left: s.left,
            right: s.right,
            parent: s.parent,
            count: s.count,
            s2: s.s2,
            radius: s.radius,
            s1: s.s1,
            sg: s.sg,
            spsi: s.spsi,
            div,
        };
        Ok(VdtModel {
            tree,
            partition,
            sigma: s.sigma,
            refiner: None,
            scratch_pool: std::sync::Mutex::new(Vec::new()),
            provenance: if s.meta_name.is_empty() { None } else { Some(s.meta_name) },
            epoch: s.epoch,
            parent_sum: s.parent_sum,
        })
    }

    /// Write the fitted model to a versioned binary snapshot at `path`
    /// (`meta_name` records dataset provenance in the file). See
    /// [`crate::runtime::snapshot`] for the format and its guarantees.
    pub fn save(&self, path: impl AsRef<Path>, meta_name: &str) -> Result<()> {
        self.to_snapshot(meta_name).write_file(path.as_ref())
    }

    /// Load a model previously written by [`VdtModel::save`] — the serving
    /// warm-start path: milliseconds instead of a full refit.
    pub fn load(path: impl AsRef<Path>) -> Result<VdtModel> {
        Self::from_snapshot(Snapshot::read_file(path.as_ref())?)
    }

    /// Approximate resident memory of the model in bytes (for the paper's
    /// memory-vs-N comparisons): tree statistics + blocks + marks.
    pub fn memory_bytes(&self) -> usize {
        let nn = self.tree.num_nodes();
        let tree = nn * (4 * 4 + 8 + 4) + self.tree.s1.len() * 4;
        let blocks = self.partition.blocks.len() * std::mem::size_of::<super::partition::Block>();
        let marks: usize =
            self.partition.marks.iter().map(|m| m.len() * 4 + 24).sum::<usize>();
        tree + blocks + marks
    }
}

impl TransitionOp for VdtModel {
    fn n(&self) -> usize {
        self.tree.n
    }

    fn matvec_into(&self, y: &Matrix, out: &mut Matrix) {
        VdtModel::matmul_into(self, y, out);
    }

    fn matvec(&self, y: &Matrix) -> Matrix {
        VdtModel::matmul(self, y)
    }

    fn matmul_into(&self, y: &Matrix, out: &mut Matrix) {
        VdtModel::matmul_into(self, y, out);
    }

    fn matmul(&self, y: &Matrix) -> Matrix {
        VdtModel::matmul(self, y)
    }

    fn card(&self) -> ModelCard {
        ModelCard {
            name: String::new(),
            backend: Backend::Vdt,
            divergence: self.tree.div.name().to_string(),
            n: self.tree.n,
            params: self.num_blocks(),
            sigma: Some(self.sigma),
            provenance: self.provenance.clone(),
            epoch: self.epoch,
            pending_ingest: 0,
            ingested_points: 0,
        }
    }

    fn snapshot(&self) -> Result<Snapshot, VdtError> {
        Ok(self.to_snapshot(self.provenance.as_deref().unwrap_or("")))
    }

    fn query_dim(&self) -> Option<usize> {
        Some(self.tree.d)
    }

    fn inductive_into(&self, x: &[f32], out: &mut [f32]) -> Result<(), VdtError> {
        let row = super::induct::try_inductive_row(self, x)?;
        row.expand_into(&self.tree, out);
        Ok(())
    }

    /// Q's row `i` without materializing Q: walk leaf `i`'s path to the
    /// root and expand each marked block `(A, B)` on it — `i ∈ leaves(A)`
    /// by construction, so `q_AB` covers `out[j]` for every
    /// `j ∈ leaves(B)`. The alive blocks tile the off-diagonal exactly
    /// (see [`super::partition::BlockPartition::validate`]), so every
    /// `j ≠ i` is written once and `out[i]` stays 0 (`q_ii = 0`). Writes
    /// `blk.q as f32`, identical to `materialize()` and to the f64
    /// matvec of the indicator column (one term, unit weight).
    fn transition_row_into(&self, i: usize, out: &mut [f32]) -> Result<(), VdtError> {
        let n = self.tree.n;
        if i >= n {
            return Err(VdtError::ShapeMismatch { what: "row index", expected: n, got: i });
        }
        if out.len() != n {
            return Err(VdtError::ShapeMismatch { what: "row buffer", expected: n, got: out.len() });
        }
        out.fill(0.0);
        let mut a = i as u32;
        loop {
            for &bi in &self.partition.marks[a as usize] {
                let blk = &self.partition.blocks[bi as usize];
                let q = blk.q as f32;
                for &j in &self.tree.leaves_under(blk.kernel) {
                    out[j as usize] = q;
                }
            }
            let p = self.tree.parent[a as usize];
            if p == crate::tree::NONE {
                break;
            }
            a = p;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn build_fit_refine_roundtrip() {
        let ds = synthetic::two_moons(80, 0.08, 1);
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        assert_eq!(m.num_blocks(), 2 * (80 - 1));
        assert!(m.sigma() > 0.0);
        let ll0 = m.loglik();
        m.refine_to(6 * 80);
        assert!(m.num_blocks() >= 6 * 80);
        assert!(m.loglik() >= ll0 - 1e-6, "refinement decreased ℓ");
        m.partition.validate(&m.tree).unwrap();
    }

    #[test]
    fn explicit_euclidean_build_matches_default() {
        // the enum-driven and generic entry points must agree bit-for-bit
        let ds = synthetic::two_moons(50, 0.08, 9);
        let a = VdtModel::build(&ds.x, &VdtConfig::default());
        let b = VdtModel::build_with(
            &ds.x,
            &VdtConfig::default(),
            crate::core::divergence::SqEuclidean,
        );
        assert_eq!(a.sigma(), b.sigma());
        assert_eq!(a.materialize().data, b.materialize().data);
        assert_eq!(a.divergence_name(), "sq_euclidean");
    }

    #[test]
    fn fixed_sigma_respected() {
        let ds = synthetic::two_moons(40, 0.08, 2);
        let cfg = VdtConfig { sigma: Some(0.37), ..Default::default() };
        let m = VdtModel::build(&ds.x, &cfg);
        assert!((m.sigma() - 0.37).abs() < 1e-12);
    }

    #[test]
    fn matvec_row_stochastic_after_refinement() {
        let ds = synthetic::two_moons(60, 0.08, 3);
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        m.refine_to(5 * 60);
        let ones = Matrix::from_fn(60, 1, |_, _| 1.0);
        let out = m.matvec(&ones);
        for &v in &out.data {
            assert!((v - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn snapshot_roundtrip_preserves_model_state() {
        let ds = synthetic::two_moons(40, 0.08, 6);
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        m.refine_to(3 * 40);
        let snap = m.to_snapshot("moons40");
        assert_eq!(snap.meta_name, "moons40");
        assert_eq!(snap.num_blocks(), m.num_blocks());
        let l = VdtModel::from_snapshot(snap).unwrap();
        assert_eq!(l.sigma().to_bits(), m.sigma().to_bits());
        assert_eq!(l.num_blocks(), m.num_blocks());
        assert_eq!(l.divergence_name(), m.divergence_name());
        let y = Matrix::from_fn(40, 2, |r, c| ((r * 3 + c) % 7) as f32 - 3.0);
        assert_eq!(m.matvec(&y).data, l.matvec(&y).data, "matvec drifted across snapshot");
        l.partition.validate(&l.tree).unwrap();
        // a loaded model stays refinable: derived state rebuilds on demand
        let mut l = l;
        l.refine_to(5 * 40);
        assert!(l.num_blocks() >= 5 * 40);
        l.partition.validate(&l.tree).unwrap();
    }

    #[test]
    fn memory_grows_with_refinement() {
        let ds = synthetic::two_moons(64, 0.08, 4);
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        let before = m.memory_bytes();
        m.refine_to(8 * 64);
        assert!(m.memory_bytes() > before);
    }
}
