//! Bandwidth learning (paper §4.2).
//!
//! Given fixed q, ℓ(D) is quasi-concave in σ with the closed-form maximizer
//! of Eq. (12):  σ*² = Σ_(A,B) q_AB·D²_AB / (N·d).
//!
//! For the fully-refined (singleton) model Eq. (14) gives a q-independent
//! initializer: σ₀ = (1/N)·sqrt(Σ_i Σ_{j≠i} ||x_i−x_j||² / d), which we
//! compute in O(N·d) from the global statistics
//! Σ_ij ||x_i−x_j||² = 2N·S2(root) − 2·||S1(root)||².
//!
//! The fit loop alternates `optimize_q` and Eq. (12) until σ stabilizes —
//! the paper observes fast, initialization-insensitive convergence, which
//! `fit_alternating` asserts in its tests.

use crate::tree::PartitionTree;

use super::optimize::{loglik, optimize_q, OptScratch};
use super::partition::BlockPartition;

/// Eq. (14): q-independent σ from the global pairwise divergence mass.
///
/// `Σ_i Σ_{j≠i} d(x_i ‖ x_j) = D_{root,root}` (the diagonal contributes
/// `d(x,x) = 0`), so the initializer is divergence-generic in O(d) from
/// the root statistics. Under squared Euclidean the block evaluation is
/// `2N·S2(root) − 2·‖S1(root)‖²` with the exact seed arithmetic
/// (`n·s2 + n·s2` and `fl(2n·s2)` are bitwise identical because doubling
/// is exact in IEEE-754), so the Euclidean path is bit-exact with the
/// pre-refactor formula — pinned by `rust/tests/fig2_golden.rs`.
pub fn sigma_init(tree: &PartitionTree) -> f64 {
    let root = tree.root();
    let n = tree.n as f64;
    let d = tree.d as f64;
    let total = tree.d2_between(root, root);
    ((total / d).sqrt() / n).max(1e-12)
}

/// Scale-aware lower clamp for the learned bandwidth.
///
/// Duplicate-heavy data makes the alternating fit collapse: q concentrates
/// on zero-divergence blocks, Eq. (12)'s numerator `Σ q·D` shrinks, and σ
/// spirals toward the old absolute floor of 1e-12 — a degenerate kernel
/// whose energies `D/(2σ²)` overflow any useful dynamic range. Flooring at
/// a tiny multiple of the data-scale σ₀ of Eq. (14) keeps the fit finite
/// and Q row-stochastic while being far (6 orders of magnitude) below any
/// bandwidth a non-degenerate fit produces, so regular fits are unaffected
/// bit-for-bit.
pub fn sigma_floor(tree: &PartitionTree) -> f64 {
    (1e-6 * sigma_init(tree)).max(1e-12)
}

/// Eq. (12): closed-form σ* given the current q, clamped at
/// [`sigma_floor`] against the duplicate-data collapse.
///
/// The O(|B|) sum runs through [`crate::core::par::par_sum_f64`]; its
/// fixed-block accumulation keeps the value identical for every thread
/// count.
pub fn sigma_update(tree: &PartitionTree, part: &BlockPartition) -> f64 {
    let blocks = &part.blocks;
    let acc = crate::core::par::par_sum_f64(blocks.len(), |bi| {
        let b = &blocks[bi];
        if b.alive {
            b.q * b.d2
        } else {
            0.0
        }
    });
    (acc / (tree.n as f64 * tree.d as f64)).sqrt().max(sigma_floor(tree))
}

/// Outcome of the alternating fit.
pub struct FitResult {
    pub sigma: f64,
    pub loglik: f64,
    pub iterations: usize,
}

/// Alternate q-optimization (Alg. 3) and σ updates (Eq. 12) until
/// |Δσ|/σ < `tol` or `max_iters`.
pub fn fit_alternating(
    tree: &PartitionTree,
    part: &mut BlockPartition,
    sigma0: Option<f64>,
    tol: f64,
    max_iters: usize,
) -> FitResult {
    let mut sigma = sigma0.unwrap_or_else(|| sigma_init(tree));
    let mut scratch = OptScratch::default();
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        optimize_q(tree, part, sigma, &mut scratch);
        let next = sigma_update(tree, part);
        let rel = (next - sigma).abs() / sigma.max(1e-12);
        sigma = next;
        if rel < tol {
            break;
        }
    }
    // final q at the converged bandwidth
    optimize_q(tree, part, sigma, &mut scratch);
    FitResult { sigma, loglik: loglik(tree, part, sigma), iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Matrix;
    use crate::data::synthetic;
    use crate::tree::{build_tree, BuildConfig, NONE};

    fn tree_of(n: usize, seed: u64) -> PartitionTree {
        let ds = synthetic::gaussian_mixture(n, 4, 2, 2, 2.0, seed, "t");
        build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() })
    }

    /// Exact (f64) row sums of Q from the block structure: row i sums
    /// `|B|·q_AB` over the marks on its leaf-to-root path.
    fn row_sums_f64(t: &PartitionTree, p: &BlockPartition) -> Vec<f64> {
        (0..t.n as u32)
            .map(|leaf| {
                let mut a = leaf;
                let mut sum = 0f64;
                loop {
                    for &bi in &p.marks[a as usize] {
                        let b = &p.blocks[bi as usize];
                        sum += t.count[b.kernel as usize] as f64 * b.q;
                    }
                    let par = t.parent[a as usize];
                    if par == NONE {
                        break;
                    }
                    a = par;
                }
                sum
            })
            .collect()
    }

    #[test]
    fn sigma_init_matches_bruteforce_eq14() {
        let ds = synthetic::gaussian_mixture(25, 4, 2, 2, 2.0, 5, "t");
        let t = build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        let mut total = 0f64;
        for i in 0..25 {
            for j in 0..25 {
                if i != j {
                    total += crate::core::vecmath::sq_dist(ds.x.row(i), ds.x.row(j));
                }
            }
        }
        let want = (total / 4.0).sqrt() / 25.0;
        assert!((sigma_init(&t) - want).abs() < 1e-6 * want);
    }

    #[test]
    fn alternating_fit_converges_and_improves_ll() {
        let t = tree_of(60, 2);
        let mut p = BlockPartition::coarsest(&t);
        let r = fit_alternating(&t, &mut p, None, 1e-6, 100);
        assert!(r.iterations < 100, "did not converge");
        assert!(r.sigma > 0.0 && r.sigma.is_finite());

        // ℓ at (q*, σ*) must beat ℓ at (q(σ0), σ0)
        let mut p0 = BlockPartition::coarsest(&t);
        let s0 = sigma_init(&t);
        super::optimize_q(&t, &mut p0, s0, &mut OptScratch::default());
        let l0 = loglik(&t, &p0, s0);
        assert!(r.loglik >= l0 - 1e-9, "fit {l} < init {l0}", l = r.loglik);
    }

    #[test]
    fn fit_insensitive_to_initial_sigma() {
        let t = tree_of(50, 3);
        let mut pa = BlockPartition::coarsest(&t);
        let mut pb = BlockPartition::coarsest(&t);
        let ra = fit_alternating(&t, &mut pa, Some(0.05), 1e-8, 200);
        let rb = fit_alternating(&t, &mut pb, Some(50.0), 1e-8, 200);
        let rel = (ra.sigma - rb.sigma).abs() / ra.sigma;
        assert!(rel < 1e-3, "σ from 0.05 -> {}, from 50 -> {}", ra.sigma, rb.sigma);
    }

    #[test]
    fn duplicate_rows_keep_bandwidth_clamped_and_q_stochastic() {
        // Every row duplicated: q concentrates on the zero-divergence
        // sibling blocks and the raw Eq. (12) fixed point collapses toward
        // 0. The sigma_floor clamp must keep the fit finite and Q exactly
        // row-stochastic (regression for the degenerate-bandwidth bug).
        let base = synthetic::gaussian_mixture(15, 3, 2, 2, 2.0, 21, "t");
        let mut x = Matrix::zeros(30, 3);
        for i in 0..30 {
            x.row_mut(i).copy_from_slice(base.x.row(i / 2));
        }
        let t = build_tree(&x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        let mut p = BlockPartition::coarsest(&t);
        let r = fit_alternating(&t, &mut p, None, 1e-10, 400);
        assert!(r.sigma.is_finite() && r.sigma > 0.0);
        assert!(r.sigma >= sigma_floor(&t), "σ {} below floor {}", r.sigma, sigma_floor(&t));
        assert!(r.loglik.is_finite(), "ℓ diverged: {}", r.loglik);
        for (i, s) in row_sums_f64(&t, &p).iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-9, "row {i} sums to {s}");
        }
    }

    #[test]
    fn all_identical_rows_stay_finite() {
        // The fully degenerate case: every pairwise divergence is 0, so
        // σ pins to its (tiny) floor and Q must still be a uniform
        // row-stochastic matrix with finite ℓ.
        let mut x = Matrix::zeros(12, 3);
        for i in 0..12 {
            x.row_mut(i).copy_from_slice(&[0.5, -1.0, 2.0]);
        }
        let t = build_tree(&x, &BuildConfig { divisive_threshold: 4, ..Default::default() });
        let mut p = BlockPartition::coarsest(&t);
        let r = fit_alternating(&t, &mut p, None, 1e-8, 100);
        assert!(r.sigma.is_finite() && r.sigma > 0.0);
        assert!(r.loglik.is_finite());
        for (i, s) in row_sums_f64(&t, &p).iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-9, "row {i} sums to {s}");
        }
    }

    #[test]
    fn sigma_update_is_stationary_point() {
        // at (q*, σ*), one more σ update changes nothing
        let t = tree_of(40, 4);
        let mut p = BlockPartition::coarsest(&t);
        let r = fit_alternating(&t, &mut p, None, 1e-10, 300);
        let again = sigma_update(&t, &p);
        assert!((again - r.sigma).abs() / r.sigma < 1e-6);
    }
}
