//! O(|B|) optimization of the variational lower bound — the equivalent of
//! Thiesson & Kim (2012) Algorithm 3, derived as a hierarchical softmax.
//!
//! Problem (paper Eq. 7 s.t. Eq. 16): maximize over q ≥ 0
//!
//! ```text
//!   ℓ(D) = c − Σ_(A,B) q_AB·D²_AB/(2σ²) − Σ_(A,B) |A||B|·q_AB·log q_AB
//!   s.t.  Σ_{(A,B) ∈ B(x_i)} |B|·q_AB = 1   for every row i
//! ```
//!
//! With `G_AB = −D²_AB/(2σ²|A||B|)` the KKT conditions collapse to a
//! two-pass recursion (DESIGN.md §4.2):
//!
//! **Up:** `log Z_A = logsumexp({log|B| + G_AB} ∪ {w_l·log Z_l + w_r·log Z_r})`
//! where `w_c = |A_c|/|A|` (leaf nodes omit the child term).
//!
//! **Down:** with per-row mass `m_root = 1`:
//! `q_AB = m_A · exp(G_AB − log Z_A)` and both children receive
//! `m_child = m_A · exp(w_l·log Z_l + w_r·log Z_r − log Z_A)`.
//!
//! Rows sum to one by construction; optimality follows by induction on the
//! per-node value function `h_A(m) = m(log Z_A − log m)` (each node solves
//! an entropy-regularized allocation whose "below" partition function is
//! the count-weighted geometric mean of the children's). Node ids are
//! created children-before-parents, so ascending id order is a valid
//! bottom-up schedule and descending order a valid top-down one.

use crate::core::par;
use crate::tree::{PartitionTree, NONE};

use super::partition::BlockPartition;

/// Blocks below this count keep the whole update serial (the parallel
/// precompute/write-back passes don't pay for themselves).
const PAR_MIN_BLOCKS: usize = 4096;

/// Scratch buffers reused across [`optimize_q`] calls (the fit loop calls
/// it once per σ update; refinement once per re-optimization).
#[derive(Default)]
pub struct OptScratch {
    log_z: Vec<f64>,
    log_m: Vec<f64>,
    terms: Vec<f64>,
    /// Per-block `G_AB` (parallel precompute; reused by the q write-back).
    g: Vec<f64>,
    /// Per-block `log|B| + G_AB` — the mark terms of the up-pass.
    logit: Vec<f64>,
}

/// `G_AB` for one block.
#[inline]
pub fn g_of(tree: &PartitionTree, data: u32, kernel: u32, d2: f64, sigma: f64) -> f64 {
    let na = tree.count[data as usize] as f64;
    let nb = tree.count[kernel as usize] as f64;
    -d2 / (2.0 * sigma * sigma * na * nb)
}

/// Globally optimize all `q_AB` in place. O(|B| + N).
///
/// The O(|B|) work — evaluating `G_AB` for every block and exponentiating
/// the final `q_AB` — runs on [`crate::core::par`] when |B| is large; the
/// two O(N) tree sweeps in between are inherently ordered (children before
/// parents and back) and stay serial. Each block's values are computed by
/// the same scalar expressions in both modes, so parallel and serial
/// results are bit-identical.
pub fn optimize_q(
    tree: &PartitionTree,
    part: &mut BlockPartition,
    sigma: f64,
    scratch: &mut OptScratch,
) {
    let _t = crate::core::obs::stage_timer("optimize_q");
    let nn = tree.num_nodes();
    let nblocks = part.blocks.len();
    scratch.log_z.clear();
    scratch.log_z.resize(nn, f64::NEG_INFINITY);
    scratch.log_m.clear();
    scratch.log_m.resize(nn, f64::NEG_INFINITY);

    // ---- per-block precompute: G_AB and log|B| + G_AB ----
    let parallel = par::is_parallel() && nblocks >= PAR_MIN_BLOCKS;
    {
        // dead (refined-away) blocks stay in the vec for index stability;
        // their slots are never read (marks and the write-back are
        // alive-only), so skip the G/ln work for them
        let blocks = &part.blocks;
        let g_at = |bi: usize| {
            let blk = &blocks[bi];
            if !blk.alive {
                return 0.0;
            }
            g_of(tree, blk.data, blk.kernel, blk.d2, sigma)
        };
        if parallel {
            par::par_fill_f64(&mut scratch.g, nblocks, g_at);
        } else {
            scratch.g.clear();
            scratch.g.extend((0..nblocks).map(g_at));
        }
        let g = &scratch.g;
        let logit_at = |bi: usize| {
            let blk = &blocks[bi];
            if !blk.alive {
                return f64::NEG_INFINITY;
            }
            let nb = tree.count[blk.kernel as usize] as f64;
            nb.ln() + g[bi]
        };
        if parallel {
            par::par_fill_f64(&mut scratch.logit, nblocks, logit_at);
        } else {
            scratch.logit.clear();
            scratch.logit.extend((0..nblocks).map(logit_at));
        }
    }

    // ---- bottom-up: log Z (ascending ids = children before parents) ----
    for a in 0..nn as u32 {
        let ai = a as usize;
        scratch.terms.clear();
        for &bi in &part.marks[ai] {
            scratch.terms.push(scratch.logit[bi as usize]);
        }
        if !tree.is_leaf(a) {
            let (l, r) = (tree.left[ai] as usize, tree.right[ai] as usize);
            let ca = tree.count[ai] as f64;
            let (wl, wr) = (tree.count[l] as f64 / ca, tree.count[r] as f64 / ca);
            scratch.terms.push(wl * scratch.log_z[l] + wr * scratch.log_z[r]);
        }
        scratch.log_z[ai] = crate::core::vecmath::logsumexp(&scratch.terms);
    }

    // ---- top-down: masses (serial O(N) sweep over internal nodes) ----
    let root = tree.root() as usize;
    scratch.log_m[root] = 0.0;
    for a in (0..nn as u32).rev() {
        let ai = a as usize;
        let lm = scratch.log_m[ai];
        if !lm.is_finite() && tree.parent[ai] != NONE {
            // unreachable mass (can only happen on degenerate single-node
            // trees); guard anyway
            continue;
        }
        if !tree.is_leaf(a) {
            let (l, r) = (tree.left[ai] as usize, tree.right[ai] as usize);
            let ca = tree.count[ai] as f64;
            let (wl, wr) = (tree.count[l] as f64 / ca, tree.count[r] as f64 / ca);
            let below = wl * scratch.log_z[l] + wr * scratch.log_z[r];
            let child_lm = lm + below - scratch.log_z[ai];
            scratch.log_m[l] = child_lm;
            scratch.log_m[r] = child_lm;
        }
    }

    // ---- per-block write-back: q_AB = exp(m_A + G_AB − log Z_A) ----
    {
        let g = &scratch.g;
        let log_m = &scratch.log_m;
        let log_z = &scratch.log_z;
        let parent = &tree.parent;
        let write_q = |start: usize, chunk: &mut [super::partition::Block]| {
            for (off, blk) in chunk.iter_mut().enumerate() {
                if !blk.alive {
                    continue;
                }
                let ai = blk.data as usize;
                let lm = log_m[ai];
                if !lm.is_finite() && parent[ai] != NONE {
                    continue; // unreachable mass: mirror the sweep guard
                }
                blk.q = (lm + g[start + off] - log_z[ai]).exp();
            }
        };
        if parallel {
            par::par_slices_mut(&mut part.blocks[..], 1, PAR_MIN_BLOCKS, write_q);
        } else {
            write_q(0, &mut part.blocks[..]);
        }
    }
}

/// The constant `c` of Eq. (7):
/// `c = −N·log((2π)^{d/2} σ^d (N−1))`.
pub fn loglik_constant(n: usize, d: usize, sigma: f64) -> f64 {
    let n_f = n as f64;
    let d_f = d as f64;
    -n_f * (0.5 * d_f * (2.0 * std::f64::consts::PI).ln() + d_f * sigma.ln() + (n_f - 1.0).ln())
}

/// Evaluate the lower bound ℓ(D) of Eq. (7) for the current q.
///
/// The per-block sum runs through [`par::par_sum_f64`], whose fixed-block
/// accumulation makes the result identical for every thread count.
pub fn loglik(tree: &PartitionTree, part: &BlockPartition, sigma: f64) -> f64 {
    let inv = 1.0 / (2.0 * sigma * sigma);
    let blocks = &part.blocks;
    let contribution = par::par_sum_f64(blocks.len(), |bi| {
        let b = &blocks[bi];
        if !b.alive || b.q <= 0.0 {
            return 0.0;
        }
        let na = tree.count[b.data as usize] as f64;
        let nb = tree.count[b.kernel as usize] as f64;
        -(b.q * b.d2 * inv) - na * nb * b.q * b.q.ln()
    });
    loglik_constant(tree.n, tree.d, sigma) + contribution
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Matrix;
    use crate::data::synthetic;
    use crate::tree::{build_tree, BuildConfig};

    fn setup(n: usize, seed: u64) -> (Matrix, PartitionTree) {
        let ds = synthetic::gaussian_mixture(n, 3, 2, 2, 2.0, seed, "t");
        let t = build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        (ds.x, t)
    }

    fn optimized(t: &PartitionTree, sigma: f64) -> BlockPartition {
        let mut p = BlockPartition::coarsest(t);
        optimize_q(t, &mut p, sigma, &mut OptScratch::default());
        p
    }

    #[test]
    fn rows_sum_to_one() {
        for n in [2usize, 5, 16, 40] {
            let (_, t) = setup(n, n as u64 + 1);
            let p = optimized(&t, 1.0);
            let q = p.materialize(&t);
            for (i, s) in q.row_sums().iter().enumerate() {
                assert!((s - 1.0).abs() < 1e-5, "n={n} row {i} sums to {s}");
            }
        }
    }

    #[test]
    fn q_nonnegative_and_finite() {
        let (_, t) = setup(30, 3);
        let p = optimized(&t, 0.5);
        for (_, b) in p.alive_blocks() {
            assert!(b.q.is_finite() && b.q >= 0.0);
        }
    }

    #[test]
    fn singleton_partition_recovers_exact_posteriors() {
        // With all-singleton blocks the constrained optimum IS the true
        // posterior matrix P of Eq. (3).
        let (x, t) = setup(10, 4);
        let sigma = 0.8;
        let mut p = BlockPartition::singletons(&t);
        optimize_q(&t, &mut p, sigma, &mut OptScratch::default());
        let q = p.materialize(&t);
        // dense reference
        let n = x.rows;
        for i in 0..n {
            let mut krow = vec![0f64; n];
            let mut s = 0f64;
            for j in 0..n {
                if j != i {
                    let d2 = crate::core::vecmath::sq_dist(x.row(i), x.row(j));
                    krow[j] = (-d2 / (2.0 * sigma * sigma)).exp();
                    s += krow[j];
                }
            }
            for j in 0..n {
                let want = (krow[j] / s) as f32;
                assert!(
                    (q.get(i, j) - want).abs() < 1e-5,
                    "P[{i},{j}] = {} want {want}",
                    q.get(i, j)
                );
            }
        }
    }

    #[test]
    fn kkt_within_node_exchange_cannot_improve() {
        // Feasible perturbation: move mass between two marks of the same
        // node (keeps every row constraint). ℓ must not increase.
        // The coarsest partition has one mark per node, so manually split
        // one block (A,B), B internal, into (A,B_l),(A,B_r) first — giving
        // node A two marks — and re-optimize globally.
        let (_, t) = setup(24, 7);
        let sigma = 1.2;
        let mut p = BlockPartition::coarsest(&t);
        let bi = p
            .alive_blocks()
            .find(|(_, b)| !t.is_leaf(b.kernel))
            .map(|(i, _)| i)
            .expect("some block with internal kernel");
        let blk = p.blocks[bi as usize].clone();
        let (bl, br) = (t.left[blk.kernel as usize], t.right[blk.kernel as usize]);
        p.kill_block(bi);
        p.push_block(blk.data, bl, t.d2_between(blk.data, bl));
        p.push_block(blk.data, br, t.d2_between(blk.data, br));
        optimize_q(&t, &mut p, sigma, &mut OptScratch::default());
        p.validate(&t).unwrap();
        let base = loglik(&t, &p, sigma);
        let node_with_two = (0..t.num_nodes())
            .find(|&a| p.marks[a].len() >= 2)
            .expect("refined partition needed");
        let (b1, b2) = (p.marks[node_with_two][0], p.marks[node_with_two][1]);
        let nb1 = t.count[p.blocks[b1 as usize].kernel as usize] as f64;
        let nb2 = t.count[p.blocks[b2 as usize].kernel as usize] as f64;
        for eps in [1e-4, -1e-4] {
            let mut p2 = p.clone();
            // |B1| dq1 = -|B2| dq2 keeps row sums
            p2.blocks[b1 as usize].q += eps / nb1;
            p2.blocks[b2 as usize].q -= eps / nb2;
            if p2.blocks[b1 as usize].q < 0.0 || p2.blocks[b2 as usize].q < 0.0 {
                continue;
            }
            let perturbed = loglik(&t, &p2, sigma);
            assert!(
                perturbed <= base + 1e-9,
                "perturbation improved ℓ: {perturbed} > {base}"
            );
        }
        // restore (p consumed above via clones; keep p alive for lint)
        let _ = &mut p;
    }

    #[test]
    fn optimum_beats_uniform_feasible_q() {
        // uniform over each row's path blocks is feasible; optimum must win
        let (_, t) = setup(18, 9);
        let sigma = 1.0;
        let p_opt = optimized(&t, sigma);
        let best = loglik(&t, &p_opt, sigma);

        // feasible "uniform" assignment: every row spreads mass equally
        // over the (N-1) kernel slots => q_AB = 1/(N-1) for all blocks.
        let mut p_uni = BlockPartition::coarsest(&t);
        let nminus1 = (t.n - 1) as f64;
        for b in p_uni.blocks.iter_mut() {
            b.q = 1.0 / nminus1;
        }
        let uni = loglik(&t, &p_uni, sigma);
        assert!(best >= uni - 1e-9, "optimum {best} < uniform {uni}");
    }

    #[test]
    fn finer_partition_has_higher_bound() {
        // singleton partition is a refinement of coarsest -> ℓ must be >=
        let (_, t) = setup(12, 11);
        let sigma = 0.9;
        let coarse = optimized(&t, sigma);
        let l_coarse = loglik(&t, &coarse, sigma);
        let mut fine = BlockPartition::singletons(&t);
        optimize_q(&t, &mut fine, sigma, &mut OptScratch::default());
        let l_fine = loglik(&t, &fine, sigma);
        assert!(l_fine >= l_coarse - 1e-9, "{l_fine} < {l_coarse}");
    }

    #[test]
    fn two_point_tree_q_is_one() {
        let x = Matrix::from_vec(vec![0.0, 0.0, 1.0, 1.0], 2, 2);
        let t = build_tree(&x, &BuildConfig::default());
        let p = optimized(&t, 1.0);
        for (_, b) in p.alive_blocks() {
            assert!((b.q - 1.0).abs() < 1e-12);
        }
    }
}
