//! Online ingest: incremental insertion of new points into a fitted
//! [`VdtModel`] without a global refit.
//!
//! Each ingested row is routed root→leaf by divergence-nearest anchor and
//! grafted into the tree ([`crate::tree::insert`]), after which the block
//! partition is surgically repaired so it still tiles the (now one larger)
//! off-diagonal exactly: blocks that referenced the routed leaf expand to
//! the new two-point graft node, and the twin pair `(leaf, new)` /
//! `(new, leaf)` is appended — mirroring the coarsest construction's
//! sibling pairs. Block energies `D_AB` touched by the root path are
//! recomputed exactly from the updated sufficient statistics, and the
//! drift each recomputation causes is accrued into a per-block
//! **staleness score** `Σ q·|ΔD|/2σ²` — an upper-bound proxy for how far
//! the block has degraded from the fitted variational bound. When a
//! block's score crosses [`IngestConfig::staleness_threshold`], that
//! block (and its mirror, per the paper's symmetric-refinement rule) is
//! locally re-split with the Eq. 18 reallocation — never a global refit.
//!
//! After every ingested batch the `q` coefficients are re-optimized
//! globally in O(|B| + N) at the **frozen** fitted bandwidth σ. This is
//! deliberately *not* a refit: σ and the pre-existing tree topology are
//! kept, which is what makes post-commit serving "refit-consistent within
//! a documented tolerance" (see `rust/tests/ingest_conformance.rs`)
//! rather than bit-identical to `fit(D ∪ d)`.
//!
//! The epoch/commit machinery that serves these updates without blocking
//! readers lives in [`crate::runtime::ingest`]; this module is the pure
//! model-mutation layer.
//!
//! ```
//! use vdt::core::Matrix;
//! use vdt::vdt::ingest::{IngestConfig, ShadowIngest};
//! use vdt::vdt::{VdtConfig, VdtModel};
//!
//! let x = Matrix::from_fn(12, 2, |r, c| ((r * 5 + c * 3) % 13) as f32);
//! let model = VdtModel::build(&x, &VdtConfig::default());
//! let mut shadow = ShadowIngest::new(model, IngestConfig::default());
//! let rows = Matrix::from_fn(2, 2, |r, _| 40.0 + r as f32);
//! shadow.ingest_rows(&rows).unwrap();
//! assert_eq!(shadow.model().n(), 14);
//! // Q is still row-stochastic over the grown point set
//! let ones = Matrix::from_fn(14, 1, |_, _| 1.0);
//! for &v in &shadow.model().matvec(&ones).data {
//!     assert!((v - 1.0).abs() < 1e-4);
//! }
//! ```

use std::collections::HashMap;

use crate::core::error::VdtError;
use crate::core::Matrix;
use crate::tree::{insert_point, route_to_leaf};

use super::model::VdtModel;
use super::optimize::{optimize_q, OptScratch};
use super::refine::split_block;

/// Knobs for the incremental-ingest path.
#[derive(Clone, Debug)]
pub struct IngestConfig {
    /// Per-block staleness budget: accumulated `q·|ΔD_AB|/2σ²` (nats of
    /// estimated bound degradation per data point of the block) beyond
    /// which the block is locally re-split. Smaller = more eager local
    /// refinement, larger |B| growth per ingested point.
    pub staleness_threshold: f64,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig { staleness_threshold: 0.25 }
    }
}

/// A mutable shadow copy of a fitted model absorbing new points.
///
/// Owns the [`VdtModel`] it mutates; readers keep serving the immutable
/// epoch the shadow was cloned from (see
/// [`crate::runtime::ingest::EpochLedger`]) until the shadow is committed
/// with [`ShadowIngest::into_model`].
pub struct ShadowIngest {
    model: VdtModel,
    cfg: IngestConfig,
    /// Accrued bound-degradation proxy per block, in lockstep with
    /// `model.partition.blocks` (indices are stable: the partition only
    /// appends and tombstones).
    staleness: Vec<f64>,
    scratch: OptScratch,
    inserted: u64,
    resplits: u64,
}

impl ShadowIngest {
    /// Wrap a model for incremental ingest. The model should be freshly
    /// fitted or snapshot-loaded; its current partition is taken as the
    /// zero-staleness reference.
    pub fn new(model: VdtModel, cfg: IngestConfig) -> ShadowIngest {
        let nblocks = model.partition.blocks.len();
        ShadowIngest {
            model,
            cfg,
            staleness: vec![0.0; nblocks],
            scratch: OptScratch::default(),
            inserted: 0,
            resplits: 0,
        }
    }

    /// The shadow model (read-only; serving never points here).
    pub fn model(&self) -> &VdtModel {
        &self.model
    }

    /// Points ingested since the shadow was created.
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Threshold-triggered local block splits performed so far.
    pub fn resplits(&self) -> u64 {
        self.resplits
    }

    /// Surrender the mutated model (the commit path).
    pub fn into_model(self) -> VdtModel {
        self.model
    }

    /// Ingest a batch of rows (one point per row, `cols == d`).
    ///
    /// Validation is atomic: *every* row is checked — shape, divergence
    /// domain, exact duplicates within the batch and against the routed
    /// leaf — before any mutation, so a failed call leaves the shadow
    /// untouched and the error is typed with the offending row index.
    /// Returns the number of points inserted.
    pub fn ingest_rows(&mut self, rows: &Matrix) -> Result<usize, VdtError> {
        let _t = crate::core::obs::stage_timer("ingest_graft");
        let d = self.model.tree.d;
        if rows.rows == 0 {
            return Err(VdtError::InvalidSpec(
                "ingest request has no rows; send at least one point".into(),
            ));
        }
        if rows.cols != d {
            return Err(VdtError::InvalidSpec(format!(
                "ingest rows have {} columns but the model dimension is d = {d}",
                rows.cols
            )));
        }
        let div = self.model.tree.div.clone();
        let mut seen: HashMap<Vec<u32>, usize> = HashMap::with_capacity(rows.rows);
        for r in 0..rows.rows {
            let x = rows.row(r);
            div.check_point(x).map_err(|reason| VdtError::Domain {
                divergence: div.name(),
                row: r,
                reason,
            })?;
            let bits: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            if let Some(&first) = seen.get(&bits) {
                return Err(VdtError::InvalidSpec(format!(
                    "ingest row {r} duplicates row {first} in the same batch; \
                     points must be distinct"
                )));
            }
            seen.insert(bits, r);
            // best-effort exact-duplicate check against the current tree:
            // the greedy descent lands on the nearest anchor chain, so an
            // exact copy of the routed leaf's point is a degenerate insert
            let leaf = route_to_leaf(&self.model.tree, x);
            if div.point_to_centroid(x, self.model.tree.s1_of(leaf), 1.0) == 0.0 {
                return Err(VdtError::InvalidSpec(format!(
                    "ingest row {r} duplicates training point {leaf} exactly; \
                     points must be distinct"
                )));
            }
        }

        // structural mutation begins: derived refine state is now stale
        self.model.invalidate_derived();
        let sigma = self.model.sigma();
        let inv_2s2 = 1.0 / (2.0 * sigma * sigma);
        for r in 0..rows.rows {
            let x = rows.row(r).to_vec();
            let out = insert_point(&mut self.model.tree, &x);
            let tree = &self.model.tree;
            let part = &mut self.model.partition;

            // --- partition surgery: renumber nodes, expand leaf→graft ---
            // marks are keyed by node id; move each list to its new slot,
            // with the routed leaf's list landing on the graft node
            let mut marks = vec![Vec::new(); tree.num_nodes()];
            for (a, ms) in std::mem::take(&mut part.marks).into_iter().enumerate() {
                let na = if a as u32 == out.old_leaf {
                    out.graft
                } else {
                    out.remap(a as u32)
                };
                marks[na as usize] = ms;
            }
            part.marks = marks;
            for b in part.blocks.iter_mut() {
                b.data = if b.data == out.old_leaf { out.graft } else { out.remap(b.data) };
                b.kernel =
                    if b.kernel == out.old_leaf { out.graft } else { out.remap(b.kernel) };
            }
            // the twin pair inside the graft, in coarsest's sibling order
            let d2_ab = tree.d2_between(out.old_leaf, out.new_leaf);
            part.push_block(out.old_leaf, out.new_leaf, d2_ab);
            let d2_ba = tree.d2_between(out.new_leaf, out.old_leaf);
            part.push_block(out.new_leaf, out.old_leaf, d2_ba);
            self.staleness.resize(part.blocks.len(), 0.0);

            // --- refresh energies touched by the root path, accrue
            //     staleness, collect threshold crossings ---
            // the graft and its ancestors are exactly the nodes whose
            // sufficient statistics changed; ids ascend toward the root,
            // so the path vector is sorted and binary-searchable
            let mut path = Vec::with_capacity(16);
            let mut a = out.graft;
            while a != crate::tree::NONE {
                path.push(a);
                a = tree.parent[a as usize];
            }
            let thresh = self.cfg.staleness_threshold;
            let mut crossed = Vec::new();
            for bi in 0..part.blocks.len() {
                let blk = &part.blocks[bi];
                if !blk.alive {
                    continue;
                }
                if path.binary_search(&blk.data).is_err()
                    && path.binary_search(&blk.kernel).is_err()
                {
                    continue;
                }
                let d2_new = tree.d2_between(blk.data, blk.kernel);
                let blk = &mut part.blocks[bi];
                self.staleness[bi] += blk.q * (d2_new - blk.d2).abs() * inv_2s2;
                blk.d2 = d2_new;
                if self.staleness[bi] > thresh {
                    crossed.push(bi as u32);
                }
            }

            // --- threshold-triggered local re-refinement (Eq. 18 splits,
            //     symmetric per §4.4) — never a global refit ---
            let _t = if crossed.is_empty() {
                None
            } else {
                Some(crate::core::obs::stage_timer("ingest_resplit"))
            };
            for bi in crossed {
                let blk = &part.blocks[bi as usize];
                if !blk.alive {
                    continue; // killed as an earlier crossing's mirror
                }
                let (ba, bb) = (blk.data, blk.kernel);
                self.staleness[bi as usize] = 0.0;
                if !tree.is_leaf(bb) {
                    split_block(tree, part, bi, sigma);
                    self.staleness.resize(part.blocks.len(), 0.0);
                    self.resplits += 1;
                }
                // mirror (B, A): the stand-in for the vertical refinement
                if !tree.is_leaf(ba) {
                    let mirror = part
                        .blocks
                        .iter()
                        .position(|b| b.alive && b.data == bb && b.kernel == ba);
                    if let Some(mi) = mirror {
                        self.staleness[mi] = 0.0;
                        split_block(tree, part, mi as u32, sigma);
                        self.staleness.resize(part.blocks.len(), 0.0);
                        self.resplits += 1;
                    }
                }
            }
            self.inserted += 1;
        }

        // one global q re-optimization per batch at the frozen fitted σ:
        // O(|B| + N), bit-identical parallel vs serial (see vdt::optimize)
        optimize_q(
            &self.model.tree,
            &mut self.model.partition,
            sigma,
            &mut self.scratch,
        );
        Ok(rows.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::divergence::DivergenceKind;
    use crate::data::synthetic;
    use crate::vdt::{VdtConfig, VdtModel};

    fn fitted(n: usize, seed: u64) -> VdtModel {
        let ds = synthetic::two_moons(n, 0.08, seed);
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        m.refine_to(4 * n);
        m
    }

    fn perturbed_rows(m: &VdtModel, k: usize, eps: f32) -> Matrix {
        let d = m.tree.d;
        Matrix::from_fn(k, d, |r, c| {
            m.tree.s1[((r * 13) % m.tree.n) * d + c] + eps * (1.0 + r as f32 + c as f32)
        })
    }

    #[test]
    fn ingest_keeps_partition_valid_and_row_stochastic() {
        let m = fitted(40, 3);
        let mut sh = ShadowIngest::new(m, IngestConfig::default());
        let rows = perturbed_rows(sh.model(), 7, 0.011);
        assert_eq!(sh.ingest_rows(&rows).unwrap(), 7);
        assert_eq!(sh.model().n(), 47);
        let m = sh.into_model();
        m.partition.validate(&m.tree).unwrap();
        let ones = Matrix::from_fn(47, 1, |_, _| 1.0);
        for (i, &v) in m.matvec(&ones).data.iter().enumerate() {
            assert!((v - 1.0).abs() < 1e-4, "row {i} sum {v}");
        }
    }

    #[test]
    fn tight_threshold_triggers_local_resplits() {
        let m = fitted(48, 5);
        let mut sh = ShadowIngest::new(m, IngestConfig { staleness_threshold: 1e-12 });
        let rows = perturbed_rows(sh.model(), 10, 0.017);
        sh.ingest_rows(&rows).unwrap();
        assert!(sh.resplits() > 0, "no local re-refinement at a tiny threshold");
        let m = sh.into_model();
        m.partition.validate(&m.tree).unwrap();
    }

    #[test]
    fn failed_batch_leaves_shadow_untouched() {
        // a 2-point tree routes by comparing the two leaves directly, so
        // an exact copy of point 0 deterministically lands on its twin
        let x = Matrix::from_fn(2, 2, |r, _| 4.0 * r as f32);
        let m = VdtModel::build(&x, &VdtConfig::default());
        let mut sh = ShadowIngest::new(m, IngestConfig::default());
        let before_n = sh.model().n();
        let before_blocks = sh.model().num_blocks();
        // row 0 is valid; row 1 duplicates training point 0 → typed error,
        // and the earlier (valid) row must not have been applied
        let bad = Matrix::from_fn(2, 2, |r, _| if r == 0 { 1.0 } else { 0.0 });
        let err = sh.ingest_rows(&bad).unwrap_err();
        assert!(matches!(err, VdtError::InvalidSpec(_)), "got {err:?}");
        assert_eq!(sh.model().n(), before_n);
        assert_eq!(sh.model().num_blocks(), before_blocks);

        // batch-internal duplicates are rejected up front too
        let twin = Matrix::from_fn(2, 2, |_, _| 1.5);
        let err = sh.ingest_rows(&twin).unwrap_err();
        assert!(matches!(err, VdtError::InvalidSpec(_)), "got {err:?}");
        assert_eq!(sh.model().n(), before_n);
    }

    #[test]
    fn out_of_domain_rows_answer_typed_domain_errors() {
        let ds = synthetic::simplex_mixture(30, 8, 2, 2, 4.0, 7, "ing_kl");
        let mut cfg = VdtConfig::default();
        cfg.divergence = DivergenceKind::Kl;
        let m = VdtModel::build(&ds.x, &cfg);
        let mut sh = ShadowIngest::new(m, IngestConfig::default());
        let bad = Matrix::from_fn(1, 8, |_, c| if c == 0 { -0.5 } else { 0.2 });
        let err = sh.ingest_rows(&bad).unwrap_err();
        match err {
            VdtError::Domain { divergence, row, .. } => {
                assert_eq!(divergence, "kl");
                assert_eq!(row, 0);
            }
            other => panic!("expected Domain error, got {other:?}"),
        }
    }

    #[test]
    fn shape_and_empty_batches_are_invalid_specs() {
        let m = fitted(24, 9);
        let mut sh = ShadowIngest::new(m, IngestConfig::default());
        let wrong_d = Matrix::from_fn(2, 5, |_, _| 0.5);
        assert!(matches!(sh.ingest_rows(&wrong_d), Err(VdtError::InvalidSpec(_))));
        let empty = Matrix::zeros(0, 2);
        assert!(matches!(sh.ingest_rows(&empty), Err(VdtError::InvalidSpec(_))));
    }
}
