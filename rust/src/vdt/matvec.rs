//! Algorithm 1: Ŷ = Q·Y in O((N + |B|)·C) using the MPT.
//!
//! **CollectUp** computes, bottom-up, `T_B = Σ_{j∈B} y_j` for every node.
//! **DistributeDown** pushes, top-down, the running sum
//! `py(A) = Σ_{(A',B) : A' ancestor-or-self} q_{A'B}·T_B` so each leaf i
//! ends up with `ŷ_i = Σ_{(A,B)∈B(x_i)} q_AB·T_B = Σ_j q_ij y_j`.
//!
//! Note: the paper's Algorithm 1 listing accumulates `|B|·q_AB·T_A`; the
//! quantity consistent with `ŷ_i = Σ_j q_ij·y_j` (and with their own
//! derivation two paragraphs above the listing) is `q_AB·T_B` — `T` of the
//! *kernel* node, unweighted, since `T_B` already sums |B| values. We
//! implement the corrected form and verify against materialized Q in tests.
//!
//! The implementation is multi-column (Y is N×C) so label propagation over
//! C classes runs all columns in one tree sweep.

use crate::core::Matrix;
use crate::tree::{PartitionTree, NONE};

use super::partition::BlockPartition;

/// Reusable buffers for [`matvec`]; sized (num_nodes × C).
#[derive(Default)]
pub struct MatvecScratch {
    /// CollectUp sums per node.
    t: Vec<f64>,
    /// DistributeDown running path sums per node.
    acc: Vec<f64>,
}

/// Ŷ = Q·Y. `y` has one row per data point (tree leaf).
pub fn matvec(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
) -> Matrix {
    assert_eq!(y.rows, tree.n, "Y rows must equal N");
    let c = y.cols;
    let nn = tree.num_nodes();
    scratch.t.clear();
    scratch.t.resize(nn * c, 0.0);
    scratch.acc.clear();
    scratch.acc.resize(nn * c, 0.0);

    // ---- CollectUp (ascending ids = children before parents) ----
    for leaf in 0..tree.n {
        for (k, &v) in y.row(leaf).iter().enumerate() {
            scratch.t[leaf * c + k] = v as f64;
        }
    }
    for a in tree.n..nn {
        let (l, r) = (tree.left[a] as usize, tree.right[a] as usize);
        for k in 0..c {
            scratch.t[a * c + k] = scratch.t[l * c + k] + scratch.t[r * c + k];
        }
    }

    // ---- DistributeDown (descending ids = parents before children) ----
    for a in (0..nn).rev() {
        let parent = tree.parent[a];
        if parent != NONE {
            let p = parent as usize;
            let (dst, src) = if a < p {
                let (lo, hi) = scratch.acc.split_at_mut(p * c);
                (&mut lo[a * c..a * c + c], &hi[..c])
            } else {
                unreachable!("parent id is always larger than child id")
            };
            dst.copy_from_slice(src);
        }
        for &bi in &part.marks[a] {
            let blk = &part.blocks[bi as usize];
            let tb = &scratch.t[blk.kernel as usize * c..blk.kernel as usize * c + c];
            for k in 0..c {
                scratch.acc[a * c + k] += blk.q * tb[k];
            }
        }
    }

    let mut out = Matrix::zeros(tree.n, c);
    for leaf in 0..tree.n {
        for k in 0..c {
            out.data[leaf * c + k] = scratch.acc[leaf * c + k] as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tree::{build_tree, BuildConfig};
    use crate::vdt::optimize::{optimize_q, OptScratch};
    use crate::vdt::partition::BlockPartition;

    fn setup(n: usize, seed: u64) -> (PartitionTree, BlockPartition) {
        let ds = synthetic::gaussian_mixture(n, 3, 2, 2, 2.0, seed, "t");
        let t = build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        let mut p = BlockPartition::coarsest(&t);
        optimize_q(&t, &mut p, 1.0, &mut OptScratch::default());
        (t, p)
    }

    #[test]
    fn matches_materialized_q() {
        for n in [2usize, 6, 17, 40] {
            let (t, p) = setup(n, n as u64);
            let y = Matrix::from_fn(n, 3, |r, c| ((r * 7 + c * 3) % 5) as f32 - 2.0);
            let want = p.materialize(&t).matmul(&y);
            let got = matvec(&t, &p, &y, &mut MatvecScratch::default());
            assert!(got.max_abs_diff(&want) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn ones_vector_maps_to_ones() {
        // rows of Q sum to 1 => Q·1 = 1
        let (t, p) = setup(30, 5);
        let ones = Matrix::from_fn(30, 1, |_, _| 1.0);
        let got = matvec(&t, &p, &ones, &mut MatvecScratch::default());
        for &v in &got.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn multicolumn_equals_stacked_single_columns() {
        let (t, p) = setup(12, 8);
        let y = Matrix::from_fn(12, 4, |r, c| ((r + c * 13) % 7) as f32);
        let multi = matvec(&t, &p, &y, &mut MatvecScratch::default());
        for col in 0..4 {
            let single = Matrix::from_fn(12, 1, |r, _| y.get(r, col));
            let got = matvec(&t, &p, &single, &mut MatvecScratch::default());
            for r in 0..12 {
                assert!((got.get(r, 0) - multi.get(r, col)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        let (t, p) = setup(15, 9);
        let y1 = Matrix::from_fn(15, 2, |r, _| r as f32);
        let y2 = Matrix::from_fn(15, 2, |r, _| -(r as f32));
        let mut s = MatvecScratch::default();
        let _ = matvec(&t, &p, &y1, &mut s);
        let b = matvec(&t, &p, &y2, &mut s);
        let fresh = matvec(&t, &p, &y2, &mut MatvecScratch::default());
        assert!(b.max_abs_diff(&fresh) == 0.0);
    }
}
