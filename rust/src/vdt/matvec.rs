//! Algorithm 1: Ŷ = Q·Y in O((N + |B|)·C) using the MPT.
//!
//! **CollectUp** computes, bottom-up, `T_B = Σ_{j∈B} y_j` for every node.
//! **DistributeDown** pushes, top-down, the running sum
//! `py(A) = Σ_{(A',B) : A' ancestor-or-self} q_{A'B}·T_B` so each leaf i
//! ends up with `ŷ_i = Σ_{(A,B)∈B(x_i)} q_AB·T_B = Σ_j q_ij y_j`.
//!
//! Note: the paper's Algorithm 1 listing accumulates `|B|·q_AB·T_A`; the
//! quantity consistent with `ŷ_i = Σ_j q_ij·y_j` (and with their own
//! derivation two paragraphs above the listing) is `q_AB·T_B` — `T` of the
//! *kernel* node, unweighted, since `T_B` already sums |B| values. We
//! implement the corrected form and verify against materialized Q in tests.
//!
//! The implementation is multi-column (Y is N×C) so label propagation over
//! C classes runs all columns in one tree sweep — and for C > 1 the
//! columns are **blocked over threads**: each worker runs the full
//! CollectUp/DistributeDown pass on its own column range with its own
//! scratch lane. Every column's arithmetic is a scalar sequence
//! independent of the blocking, so parallel output is bit-identical to
//! serial (`VDT_THREADS=1` or a single column takes the serial lane).

use crate::core::par;
use crate::core::Matrix;
use crate::tree::{PartitionTree, NONE};

use super::partition::BlockPartition;

/// One worker's reusable buffers, sized (num_nodes × its column count).
#[derive(Default)]
struct Lane {
    /// CollectUp sums per node.
    t: Vec<f64>,
    /// DistributeDown running path sums per node.
    acc: Vec<f64>,
    /// Column-block output staging (`n × block width`), interleaved into
    /// the result matrix after the join; unused by the serial lane, which
    /// writes the result matrix directly.
    out: Vec<f32>,
}

/// Reusable buffers for [`matvec`]: one [`Lane`] per column-block worker
/// (exactly one in the serial case). Lanes persist across calls, so
/// steady-state matvec (e.g. LP iterations) allocates nothing.
#[derive(Default)]
pub struct MatvecScratch {
    lanes: Vec<Lane>,
}

/// Run Algorithm 1 for the column range `c0..c1` of `y`, writing the
/// result (row-major `n × (c1-c0)`) into `out`.
#[allow(clippy::too_many_arguments)]
fn sweep_columns(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    c0: usize,
    c1: usize,
    t: &mut Vec<f64>,
    acc: &mut Vec<f64>,
    out: &mut [f32],
) {
    let cb = c1 - c0;
    let nn = tree.num_nodes();
    debug_assert_eq!(out.len(), tree.n * cb);
    t.clear();
    t.resize(nn * cb, 0.0);
    acc.clear();
    acc.resize(nn * cb, 0.0);

    // ---- CollectUp (ascending ids = children before parents) ----
    for leaf in 0..tree.n {
        for (k, &v) in y.row(leaf)[c0..c1].iter().enumerate() {
            t[leaf * cb + k] = v as f64;
        }
    }
    for a in tree.n..nn {
        let (l, r) = (tree.left[a] as usize, tree.right[a] as usize);
        for k in 0..cb {
            t[a * cb + k] = t[l * cb + k] + t[r * cb + k];
        }
    }

    // ---- DistributeDown (descending ids = parents before children) ----
    for a in (0..nn).rev() {
        let parent = tree.parent[a];
        if parent != NONE {
            let p = parent as usize;
            debug_assert!(a < p, "parent id is always larger than child id");
            let (lo, hi) = acc.split_at_mut(p * cb);
            lo[a * cb..a * cb + cb].copy_from_slice(&hi[..cb]);
        }
        for &bi in &part.marks[a] {
            let blk = &part.blocks[bi as usize];
            let tb = &t[blk.kernel as usize * cb..blk.kernel as usize * cb + cb];
            for k in 0..cb {
                acc[a * cb + k] += blk.q * tb[k];
            }
        }
    }

    for leaf in 0..tree.n {
        for k in 0..cb {
            out[leaf * cb + k] = acc[leaf * cb + k] as f32;
        }
    }
}

/// Ŷ = Q·Y. `y` has one row per data point (tree leaf).
pub fn matvec(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
) -> Matrix {
    let mut out = Matrix::zeros(tree.n, y.cols);
    matvec_into(tree, part, y, scratch, &mut out);
    out
}

/// Ŷ = Q·Y written into a caller-owned `out` (`n × y.cols`, fully
/// overwritten) — the allocation-free serving primitive: steady-state
/// request loops reuse both the scratch lanes *and* the output buffer.
pub fn matvec_into(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
    out: &mut Matrix,
) {
    assert_eq!(y.rows, tree.n, "Y rows must equal N");
    let c = y.cols;
    let n = tree.n;
    assert_eq!((out.rows, out.cols), (n, c), "output shape mismatch");
    let workers = par::effective_threads().min(c);
    if workers <= 1 || n * c < 8192 {
        // serial lane: the whole column range in one sweep, straight into
        // the result matrix
        if scratch.lanes.is_empty() {
            scratch.lanes.push(Lane::default());
        }
        let lane = &mut scratch.lanes[0];
        sweep_columns(tree, part, y, 0, c, &mut lane.t, &mut lane.acc, &mut out.data);
        return;
    }

    // column-blocked: worker w owns columns w*cb .. min((w+1)*cb, c),
    // staging into its lane's persistent out buffer (steady state
    // allocates nothing)
    let cb = c.div_ceil(workers);
    let n_blocks = c.div_ceil(cb);
    if scratch.lanes.len() < n_blocks {
        scratch.lanes.resize_with(n_blocks, Lane::default);
    }
    std::thread::scope(|s| {
        for (w, lane) in scratch.lanes.iter_mut().enumerate().take(n_blocks) {
            let c0 = w * cb;
            let c1 = (c0 + cb).min(c);
            s.spawn(move || {
                let Lane { t, acc, out } = lane;
                out.clear();
                out.resize(n * (c1 - c0), 0.0);
                sweep_columns(tree, part, y, c0, c1, t, acc, &mut out[..]);
            });
        }
    });

    // interleave the column blocks back into one row-major matrix
    for (w, lane) in scratch.lanes.iter().enumerate().take(n_blocks) {
        let c0 = w * cb;
        let width = lane.out.len() / n;
        for r in 0..n {
            out.data[r * c + c0..r * c + c0 + width]
                .copy_from_slice(&lane.out[r * width..(r + 1) * width]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tree::{build_tree, BuildConfig};
    use crate::vdt::optimize::{optimize_q, OptScratch};
    use crate::vdt::partition::BlockPartition;

    fn setup(n: usize, seed: u64) -> (PartitionTree, BlockPartition) {
        let ds = synthetic::gaussian_mixture(n, 3, 2, 2, 2.0, seed, "t");
        let t = build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        let mut p = BlockPartition::coarsest(&t);
        optimize_q(&t, &mut p, 1.0, &mut OptScratch::default());
        (t, p)
    }

    #[test]
    fn matches_materialized_q() {
        for n in [2usize, 6, 17, 40] {
            let (t, p) = setup(n, n as u64);
            let y = Matrix::from_fn(n, 3, |r, c| ((r * 7 + c * 3) % 5) as f32 - 2.0);
            let want = p.materialize(&t).matmul(&y);
            let got = matvec(&t, &p, &y, &mut MatvecScratch::default());
            assert!(got.max_abs_diff(&want) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn ones_vector_maps_to_ones() {
        // rows of Q sum to 1 => Q·1 = 1
        let (t, p) = setup(30, 5);
        let ones = Matrix::from_fn(30, 1, |_, _| 1.0);
        let got = matvec(&t, &p, &ones, &mut MatvecScratch::default());
        for &v in &got.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn multicolumn_equals_stacked_single_columns() {
        let (t, p) = setup(12, 8);
        let y = Matrix::from_fn(12, 4, |r, c| ((r + c * 13) % 7) as f32);
        let multi = matvec(&t, &p, &y, &mut MatvecScratch::default());
        for col in 0..4 {
            let single = Matrix::from_fn(12, 1, |r, _| y.get(r, col));
            let got = matvec(&t, &p, &single, &mut MatvecScratch::default());
            for r in 0..12 {
                assert!((got.get(r, 0) - multi.get(r, col)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        let (t, p) = setup(15, 9);
        let y1 = Matrix::from_fn(15, 2, |r, _| r as f32);
        let y2 = Matrix::from_fn(15, 2, |r, _| -(r as f32));
        let mut s = MatvecScratch::default();
        let _ = matvec(&t, &p, &y1, &mut s);
        let b = matvec(&t, &p, &y2, &mut s);
        let fresh = matvec(&t, &p, &y2, &mut MatvecScratch::default());
        assert!(b.max_abs_diff(&fresh) == 0.0);
    }

    #[test]
    fn column_blocked_path_is_bit_identical_to_serial_lane() {
        // big enough that n*c clears the parallel gate when threads > 1
        let (t, p) = setup(1300, 12);
        let y = Matrix::from_fn(1300, 8, |r, c| (((r * 31 + c * 17) % 23) as f32 - 11.0) * 0.3);
        let mut serial_out = Matrix::zeros(1300, 8);
        let mut lane = Lane::default();
        sweep_columns(&t, &p, &y, 0, 8, &mut lane.t, &mut lane.acc, &mut serial_out.data);
        let blocked = matvec(&t, &p, &y, &mut MatvecScratch::default());
        assert_eq!(serial_out.data, blocked.data, "column blocking changed bits");
    }
}
