//! Algorithm 1: Ŷ = Q·Y in O((N + |B|)·C) using the MPT.
//!
//! **CollectUp** computes, bottom-up, `T_B = Σ_{j∈B} y_j` for every node.
//! **DistributeDown** pushes, top-down, the running sum
//! `py(A) = Σ_{(A',B) : A' ancestor-or-self} q_{A'B}·T_B` so each leaf i
//! ends up with `ŷ_i = Σ_{(A,B)∈B(x_i)} q_AB·T_B = Σ_j q_ij y_j`.
//!
//! Note: the paper's Algorithm 1 listing accumulates `|B|·q_AB·T_A`; the
//! quantity consistent with `ŷ_i = Σ_j q_ij·y_j` (and with their own
//! derivation two paragraphs above the listing) is `q_AB·T_B` — `T` of the
//! *kernel* node, unweighted, since `T_B` already sums |B| values. We
//! implement the corrected form and verify against materialized Q in tests.
//!
//! ## Multi-RHS execution ([`matmul_into`])
//!
//! Y is N×C and the whole RHS goes through **one** pack of the block
//! partition per call: the per-node mark lists and block stats are
//! flattened into a contiguous CSR-like [`BlockPack`] (offsets + kernel
//! ids + coefficients), then the CollectUp/DistributeDown sweep applies
//! all fused columns at every node/mark visit. The column range is
//! processed in tiles of at most [`COL_TILE`] columns so the per-node
//! `t`/`acc` lanes stay cache-resident even for wide fused batches, and
//! for C > 1 the tiles are additionally **blocked over threads**: each
//! worker sweeps its own contiguous column range with its own scratch
//! lane. The inner loops go through [`crate::core::simd`] (runtime
//! AVX2/SSE2 dispatch, `VDT_SIMD` knob).
//!
//! ## Determinism
//!
//! Every column's arithmetic is a scalar sequence independent of the
//! tiling and the thread blocking, and the SIMD kernels in the default
//! tier are elementwise (per-lane IEEE ops, no FMA, no reassociation) —
//! so the output is bit-identical across `VDT_THREADS`, `VDT_SIMD∈{0,1}`,
//! tile boundaries, and C-vs-stacked-single-column execution. The only
//! exception is the opt-in `VDT_SIMD=fast` tier, which packs block
//! coefficients to f32 (accumulation stays f64); its error is bounded by
//! tests in `rust/tests/simd_kernels.rs`.

use crate::core::{par, simd};
use crate::core::Matrix;
use crate::tree::{PartitionTree, NONE};

use super::partition::BlockPartition;

/// Column-tile width: the sweep processes at most this many RHS columns
/// per tree traversal, bounding the hot `t`/`acc` working set to
/// `num_nodes × COL_TILE × 8 B` each (≈1 MB at N = 8000) so wide fused
/// batches don't fall out of L2.
const COL_TILE: usize = 8;

/// The per-call flattened view of a [`BlockPartition`]: mark lists and
/// block stats packed into one contiguous CSR-like layout so the
/// DistributeDown inner loop reads offsets/kernels/coefficients
/// sequentially instead of chasing `Vec<Vec<u32>>` spines and 40-byte
/// `Block` records. Rebuilt from the partition on every [`matmul_into`]
/// call (O(num_nodes + |B|), amortized across all column tiles and
/// workers of that call), so it can never go stale when `refine_to` /
/// `optimize_q` mutate the partition between calls.
#[derive(Default)]
struct BlockPack {
    /// CSR offsets into `kernel`/coefficients, length `num_nodes + 1`.
    off: Vec<u32>,
    /// Kernel node id per mark.
    kernel: Vec<u32>,
    /// f64 block coefficients (default tier; empty in fast mode).
    q: Vec<f64>,
    /// f32-packed coefficients (`VDT_SIMD=fast` only; empty otherwise).
    q32: Vec<f32>,
    /// Which coefficient array is populated.
    fast: bool,
}

impl BlockPack {
    fn build(&mut self, part: &BlockPartition, nn: usize, fast: bool) {
        self.off.clear();
        self.kernel.clear();
        self.q.clear();
        self.q32.clear();
        self.fast = fast;
        self.off.reserve(nn + 1);
        self.off.push(0);
        for marks in part.marks.iter().take(nn) {
            for &bi in marks {
                let blk = &part.blocks[bi as usize];
                self.kernel.push(blk.kernel);
                if fast {
                    self.q32.push(blk.q as f32);
                } else {
                    self.q.push(blk.q);
                }
            }
            self.off.push(self.kernel.len() as u32);
        }
    }
}

/// Where DistributeDown reads each node's marks from: the packed CSR view
/// (multi-column calls) or the partition directly (single-column calls,
/// where a per-call pack build would cost as much as the sweep itself).
/// Both iterate the same marks in the same order with f64 arithmetic, so
/// the two paths are bit-identical in the default tier.
#[derive(Clone, Copy)]
enum Marks<'a> {
    Pack(&'a BlockPack),
    Direct(&'a BlockPartition),
}

/// One worker's reusable buffers, sized (num_nodes × its column count).
#[derive(Default)]
struct Lane {
    /// CollectUp sums per node.
    t: Vec<f64>,
    /// DistributeDown running path sums per node.
    acc: Vec<f64>,
    /// Column-block output staging (`n × block width`), interleaved into
    /// the result matrix after the join; unused by the serial lane, which
    /// writes the result matrix directly.
    out: Vec<f32>,
}

/// Reusable buffers for [`matmul`]/[`matvec`]: the flattened block pack
/// plus one [`Lane`] per column-block worker (exactly one in the serial
/// case). Buffers persist across calls, so steady-state application (e.g.
/// LP iterations, the serving loop) allocates nothing.
#[derive(Default)]
pub struct MatvecScratch {
    pack: BlockPack,
    lanes: Vec<Lane>,
}

/// Run Algorithm 1 for the column tile `c0..c1` of `y`, writing the
/// result into `out` at row stride `out_stride`, starting at column
/// `out_col0` of each row.
#[allow(clippy::too_many_arguments)]
fn sweep_tile(
    tree: &PartitionTree,
    marks: Marks<'_>,
    y: &Matrix,
    c0: usize,
    c1: usize,
    t: &mut Vec<f64>,
    acc: &mut Vec<f64>,
    out: &mut [f32],
    out_stride: usize,
    out_col0: usize,
) {
    let cb = c1 - c0;
    let nn = tree.num_nodes();
    t.clear();
    t.resize(nn * cb, 0.0);
    acc.clear();
    acc.resize(nn * cb, 0.0);

    // ---- CollectUp (ascending ids = children before parents) ----
    for leaf in 0..tree.n {
        for (k, &v) in y.row(leaf)[c0..c1].iter().enumerate() {
            t[leaf * cb + k] = v as f64;
        }
    }
    for a in tree.n..nn {
        let (l, r) = (tree.left[a] as usize, tree.right[a] as usize);
        debug_assert!(l < a && r < a, "child ids are always smaller than the parent's");
        let (lo, hi) = t.split_at_mut(a * cb);
        simd::add_f64(&mut hi[..cb], &lo[l * cb..l * cb + cb], &lo[r * cb..r * cb + cb]);
    }

    // ---- DistributeDown (descending ids = parents before children) ----
    for a in (0..nn).rev() {
        let parent = tree.parent[a];
        if parent != NONE {
            let p = parent as usize;
            debug_assert!(a < p, "parent id is always larger than child id");
            let (lo, hi) = acc.split_at_mut(p * cb);
            lo[a * cb..a * cb + cb].copy_from_slice(&hi[..cb]);
        }
        match marks {
            Marks::Pack(pack) => {
                let (m0, m1) = (pack.off[a] as usize, pack.off[a + 1] as usize);
                if m0 == m1 {
                    continue;
                }
                let dst = &mut acc[a * cb..a * cb + cb];
                for m in m0..m1 {
                    let kn = pack.kernel[m] as usize;
                    let q = if pack.fast { pack.q32[m] as f64 } else { pack.q[m] };
                    simd::axpy_f64(dst, q, &t[kn * cb..kn * cb + cb]);
                }
            }
            Marks::Direct(part) => {
                if part.marks[a].is_empty() {
                    continue;
                }
                let dst = &mut acc[a * cb..a * cb + cb];
                for &bi in &part.marks[a] {
                    let blk = &part.blocks[bi as usize];
                    let kn = blk.kernel as usize;
                    simd::axpy_f64(dst, blk.q, &t[kn * cb..kn * cb + cb]);
                }
            }
        }
    }

    for leaf in 0..tree.n {
        let dst = &mut out[leaf * out_stride + out_col0..leaf * out_stride + out_col0 + cb];
        for (k, o) in dst.iter_mut().enumerate() {
            *o = acc[leaf * cb + k] as f32;
        }
    }
}

/// Sweep the column range `c0..c1` as consecutive tiles of at most
/// [`COL_TILE`] columns, reusing the same `t`/`acc` buffers across tiles
/// (this is the cache blocking: one tile's lanes are hot while the tree
/// and pack stream through). `out` holds rows of `out_stride` floats and
/// receives the range at columns `0..c1-c0` relative to `c0`.
#[allow(clippy::too_many_arguments)]
fn sweep_range(
    tree: &PartitionTree,
    marks: Marks<'_>,
    y: &Matrix,
    c0: usize,
    c1: usize,
    t: &mut Vec<f64>,
    acc: &mut Vec<f64>,
    out: &mut [f32],
    out_stride: usize,
) {
    let mut tc0 = c0;
    while tc0 < c1 {
        let tc1 = (tc0 + COL_TILE).min(c1);
        sweep_tile(tree, marks, y, tc0, tc1, t, acc, out, out_stride, tc0 - c0);
        tc0 = tc1;
    }
}

/// Ŷ = Q·Y. `y` has one row per data point (tree leaf). Allocates the
/// output; see [`matmul_into`] for the allocation-free form.
pub fn matmul(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
) -> Matrix {
    let mut out = Matrix::zeros(tree.n, y.cols);
    matmul_into(tree, part, y, scratch, &mut out);
    out
}

/// Backwards-compatible alias for [`matmul`] (the historical single-sweep
/// entry point; multi-column Y was always accepted).
pub fn matvec(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
) -> Matrix {
    matmul(tree, part, y, scratch)
}

/// True multi-RHS Algorithm 1: Ŷ = Q·Y written into a caller-owned `out`
/// (`n × y.cols`, fully overwritten) — the allocation-free serving
/// primitive; steady-state request loops reuse the scratch *and* the
/// output buffer.
///
/// For C > 1 the block partition is flattened into the scratch's
/// [`BlockPack`] **once per call** and shared by every column tile and
/// worker, so fused batches pay one partition traversal total instead of
/// one per column block. Output is bit-identical to C separate
/// single-column calls (and to any `VDT_THREADS` setting) in the default
/// SIMD tier; see the module docs for the `VDT_SIMD=fast` exception.
pub fn matmul_into(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
    out: &mut Matrix,
) {
    let _t = crate::core::obs::stage_timer("matmul");
    assert_eq!(y.rows, tree.n, "Y rows must equal N");
    let c = y.cols;
    let n = tree.n;
    assert_eq!((out.rows, out.cols), (n, c), "output shape mismatch");
    if c == 0 {
        return;
    }

    // single-column calls read the partition directly — a per-call pack
    // build would cost as much as the one sweep it feeds
    let use_pack = c > 1;
    if use_pack {
        scratch.pack.build(part, tree.num_nodes(), simd::fast_enabled());
    }
    let MatvecScratch { pack, lanes } = scratch;
    let marks = if use_pack { Marks::Pack(&*pack) } else { Marks::Direct(part) };

    let workers = par::effective_threads().min(c);
    if workers <= 1 || n * c < 8192 {
        // serial lane: all tiles on this thread, straight into the result
        // matrix at row stride c
        if lanes.is_empty() {
            lanes.push(Lane::default());
        }
        let lane = &mut lanes[0];
        sweep_range(tree, marks, y, 0, c, &mut lane.t, &mut lane.acc, &mut out.data, c);
        return;
    }

    // column-blocked: worker w owns columns w*cb .. min((w+1)*cb, c),
    // tiling its range and staging into its lane's persistent out buffer
    // (steady state allocates nothing)
    let cb = c.div_ceil(workers);
    let n_blocks = c.div_ceil(cb);
    if lanes.len() < n_blocks {
        lanes.resize_with(n_blocks, Lane::default);
    }
    std::thread::scope(|s| {
        for (w, lane) in lanes.iter_mut().enumerate().take(n_blocks) {
            let c0 = w * cb;
            let c1 = (c0 + cb).min(c);
            s.spawn(move || {
                let Lane { t, acc, out } = lane;
                out.clear();
                out.resize(n * (c1 - c0), 0.0);
                sweep_range(tree, marks, y, c0, c1, t, acc, &mut out[..], c1 - c0);
            });
        }
    });

    // interleave the column blocks back into one row-major matrix
    for (w, lane) in lanes.iter().enumerate().take(n_blocks) {
        let c0 = w * cb;
        let width = lane.out.len() / n;
        for r in 0..n {
            out.data[r * c + c0..r * c + c0 + width]
                .copy_from_slice(&lane.out[r * width..(r + 1) * width]);
        }
    }
}

/// Backwards-compatible alias for [`matmul_into`].
pub fn matvec_into(
    tree: &PartitionTree,
    part: &BlockPartition,
    y: &Matrix,
    scratch: &mut MatvecScratch,
    out: &mut Matrix,
) {
    matmul_into(tree, part, y, scratch, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tree::{build_tree, BuildConfig};
    use crate::vdt::optimize::{optimize_q, OptScratch};
    use crate::vdt::partition::BlockPartition;

    fn setup(n: usize, seed: u64) -> (PartitionTree, BlockPartition) {
        let ds = synthetic::gaussian_mixture(n, 3, 2, 2, 2.0, seed, "t");
        let t = build_tree(&ds.x, &BuildConfig { divisive_threshold: 8, ..Default::default() });
        let mut p = BlockPartition::coarsest(&t);
        optimize_q(&t, &mut p, 1.0, &mut OptScratch::default());
        (t, p)
    }

    #[test]
    fn matches_materialized_q() {
        for n in [2usize, 6, 17, 40] {
            let (t, p) = setup(n, n as u64);
            let y = Matrix::from_fn(n, 3, |r, c| ((r * 7 + c * 3) % 5) as f32 - 2.0);
            let want = p.materialize(&t).matmul(&y);
            let got = matvec(&t, &p, &y, &mut MatvecScratch::default());
            assert!(got.max_abs_diff(&want) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn ones_vector_maps_to_ones() {
        // rows of Q sum to 1 => Q·1 = 1
        let (t, p) = setup(30, 5);
        let ones = Matrix::from_fn(30, 1, |_, _| 1.0);
        let got = matvec(&t, &p, &ones, &mut MatvecScratch::default());
        for &v in &got.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn multicolumn_is_bit_identical_to_stacked_single_columns() {
        // the packed multi-RHS path and the direct single-column path run
        // the same per-column scalar sequence => exact equality, not just
        // tolerance
        let (t, p) = setup(12, 8);
        let y = Matrix::from_fn(12, 4, |r, c| ((r + c * 13) % 7) as f32);
        let multi = matvec(&t, &p, &y, &mut MatvecScratch::default());
        for col in 0..4 {
            let single = Matrix::from_fn(12, 1, |r, _| y.get(r, col));
            let got = matvec(&t, &p, &single, &mut MatvecScratch::default());
            for r in 0..12 {
                assert_eq!(
                    got.get(r, 0).to_bits(),
                    multi.get(r, col).to_bits(),
                    "r={r} col={col}"
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        let (t, p) = setup(15, 9);
        let y1 = Matrix::from_fn(15, 2, |r, _| r as f32);
        let y2 = Matrix::from_fn(15, 2, |r, _| -(r as f32));
        let mut s = MatvecScratch::default();
        let _ = matvec(&t, &p, &y1, &mut s);
        let b = matvec(&t, &p, &y2, &mut s);
        let fresh = matvec(&t, &p, &y2, &mut MatvecScratch::default());
        assert!(b.max_abs_diff(&fresh) == 0.0);
    }

    #[test]
    fn column_blocked_path_is_bit_identical_to_serial_lane() {
        // big enough that n*c clears the parallel gate when threads > 1
        let (t, p) = setup(1300, 12);
        let y = Matrix::from_fn(1300, 8, |r, c| (((r * 31 + c * 17) % 23) as f32 - 11.0) * 0.3);
        let mut pack = BlockPack::default();
        pack.build(&p, t.num_nodes(), false);
        let mut serial_out = Matrix::zeros(1300, 8);
        let mut lane = Lane::default();
        sweep_range(
            &t,
            Marks::Pack(&pack),
            &y,
            0,
            8,
            &mut lane.t,
            &mut lane.acc,
            &mut serial_out.data,
            8,
        );
        let blocked = matvec(&t, &p, &y, &mut MatvecScratch::default());
        assert_eq!(serial_out.data, blocked.data, "column blocking changed bits");
    }

    #[test]
    fn tiling_is_bit_invariant_for_wide_rhs() {
        // C = 19 spans two tiles serially (COL_TILE = 8) and splits
        // unevenly over workers; every grouping must produce the same bits
        // as the direct single-column path
        let (t, p) = setup(90, 21);
        let y = Matrix::from_fn(90, 19, |r, c| (((r * 13 + c * 7) % 29) as f32 - 14.0) * 0.21);
        let wide = matvec(&t, &p, &y, &mut MatvecScratch::default());
        for col in 0..19 {
            let single = Matrix::from_fn(90, 1, |r, _| y.get(r, col));
            let got = matvec(&t, &p, &single, &mut MatvecScratch::default());
            for r in 0..90 {
                assert_eq!(got.get(r, 0).to_bits(), wide.get(r, col).to_bits(), "r={r} col={col}");
            }
        }
    }

    #[test]
    fn pack_matches_partition_order() {
        let (t, p) = setup(60, 4);
        let mut pack = BlockPack::default();
        pack.build(&p, t.num_nodes(), false);
        assert_eq!(pack.off.len(), t.num_nodes() + 1);
        assert_eq!(*pack.off.last().unwrap() as usize, pack.kernel.len());
        assert_eq!(pack.q.len(), pack.kernel.len());
        assert!(pack.q32.is_empty());
        let mut m = 0usize;
        for a in 0..t.num_nodes() {
            for &bi in &p.marks[a] {
                let blk = &p.blocks[bi as usize];
                assert_eq!(pack.kernel[m], blk.kernel);
                assert_eq!(pack.q[m].to_bits(), blk.q.to_bits());
                m += 1;
            }
        }
        assert_eq!(m, pack.kernel.len());
    }
}
