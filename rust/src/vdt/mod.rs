//! The Variational Dual-Tree model — the paper's contribution.
//!
//! - [`partition`]: block partitions of P conforming to the shared tree,
//!   stored as a marked partition tree (MPT, paper §3.1).
//! - [`optimize`]: the O(|B|) constrained maximization of the variational
//!   lower bound ℓ(D), Eq. (7) s.t. Eq. (16) (Thiesson–Kim Algorithm 3 as a
//!   hierarchical-softmax recursion; DESIGN.md §4.2).
//! - [`sigma`]: closed-form bandwidth updates (Eqs. 12/14) and the
//!   alternating fit loop (paper §4.2).
//! - [`matvec`]: Algorithm 1 — Q·Y in O((N+|B|)·C).
//! - [`refine`]: greedy symmetric refinement driven by the closed-form
//!   horizontal gain Δʰ (Eqs. 17–19, paper §4.4).
//! - [`model`]: [`VdtModel`], the user-facing assembly of all of the above.
//! - [`induct`]: out-of-sample (inductive) transition rows — the paper's
//!   stated future-work extension.
//! - [`ingest`]: online ingest — incremental point insertion with
//!   staleness-triggered local re-refinement (no global refit); the
//!   epoch/commit serving machinery is [`crate::runtime::ingest`].

pub mod induct;
pub mod ingest;
pub mod matvec;
pub mod model;
pub mod optimize;
pub mod partition;
pub mod refine;
pub mod sigma;

pub use model::{VdtConfig, VdtModel};
pub use partition::{Block, BlockPartition};
