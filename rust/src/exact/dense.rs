//! Pure-Rust dense reference: Eq. (3) exactly as
//! `python/compile/kernels/ref.py` defines it. This is both the fallback
//! backend for shapes without an artifact and the cross-check oracle for
//! the XLA path (`tests/xla_roundtrip.rs`).

use crate::core::divergence::Divergence;
use crate::core::vecmath::sq_dist;
use crate::core::Matrix;

/// Dense pairwise squared distances (upper+lower, zero diagonal).
pub fn pairwise_sq_dists(x: &Matrix) -> Matrix {
    let n = x.rows;
    let mut d2 = Matrix::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            let v = sq_dist(x.row(i), x.row(j)) as f32;
            d2.set(i, j, v);
            d2.set(j, i, v);
        }
    }
    d2
}

/// Dense pairwise Bregman divergences: entry (i, j) holds `d(x_i ‖ x_j)`
/// (zero diagonal, asymmetric in general). Feeds [`transition_from_d2`]
/// and [`fit_sigma`] unchanged — both only assume nonnegative entries —
/// so the exact baseline works in any geometry.
pub fn pairwise_divergences(x: &Matrix, div: &dyn Divergence) -> Matrix {
    let n = x.rows;
    let mut d2 = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            if i != j {
                d2.set(i, j, div.point(x.row(i), x.row(j)) as f32);
            }
        }
    }
    d2
}

/// Row-stochastic P from a precomputed distance matrix: masked Gaussian
/// kernel + row normalization, with the per-row max-shift so large
/// absolute distances don't underflow every entry.
pub fn transition_from_d2(d2: &Matrix, sigma: f64) -> Matrix {
    let n = d2.rows;
    let inv = 1.0 / (2.0 * sigma * sigma);
    let mut p = Matrix::zeros(n, n);
    for i in 0..n {
        let row = d2.row(i);
        let mut dmin = f64::INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if j != i {
                dmin = dmin.min(v as f64);
            }
        }
        let mut sum = 0f64;
        for (j, &v) in row.iter().enumerate() {
            if j != i {
                let e = (-(v as f64 - dmin) * inv).exp();
                p.set(i, j, e as f32);
                sum += e;
            }
        }
        let norm = 1.0 / sum.max(1e-30);
        for j in 0..n {
            if j != i {
                p.set(i, j, (p.get(i, j) as f64 * norm) as f32);
            }
        }
    }
    p
}

/// Alternating σ fit over singleton blocks (the exact-model analogue of
/// §4.2): q = P(σ), then σ² = Σ_ij q_ij·d²_ij / (N·d).
pub fn fit_sigma(d2: &Matrix, d: usize, tol: f64, max_iters: usize) -> f64 {
    let n = d2.rows;
    // Eq. (14) initializer
    let total: f64 = d2.data.iter().map(|&v| v as f64).sum();
    let mut sigma = ((total / d as f64).sqrt() / n as f64).max(1e-12);
    for _ in 0..max_iters {
        let p = transition_from_d2(d2, sigma);
        let mut acc = 0f64;
        for i in 0..n {
            for j in 0..n {
                acc += p.get(i, j) as f64 * d2.get(i, j) as f64;
            }
        }
        let next = (acc / (n as f64 * d as f64)).sqrt().max(1e-12);
        let rel = (next - sigma).abs() / sigma;
        sigma = next;
        if rel < tol {
            break;
        }
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn d2_symmetric_zero_diag() {
        let ds = synthetic::two_moons(20, 0.05, 1);
        let d2 = pairwise_sq_dists(&ds.x);
        for i in 0..20 {
            assert_eq!(d2.get(i, i), 0.0);
            for j in 0..20 {
                assert_eq!(d2.get(i, j), d2.get(j, i));
            }
        }
    }

    #[test]
    fn transition_matches_unshifted_formula() {
        // the max-shift must not change the normalized result
        let ds = synthetic::gaussian_mixture(15, 3, 2, 1, 2.0, 2, "t");
        let d2 = pairwise_sq_dists(&ds.x);
        let sigma = 0.9f64;
        let p = transition_from_d2(&d2, sigma);
        for i in 0..15 {
            let mut k: Vec<f64> = (0..15)
                .map(|j| {
                    if j == i {
                        0.0
                    } else {
                        (-(d2.get(i, j) as f64) / (2.0 * sigma * sigma)).exp()
                    }
                })
                .collect();
            let s: f64 = k.iter().sum();
            for v in k.iter_mut() {
                *v /= s;
            }
            for j in 0..15 {
                assert!((p.get(i, j) as f64 - k[j]).abs() < 1e-6, "({i},{j})");
            }
        }
    }

    #[test]
    fn fit_sigma_fixed_point() {
        let ds = synthetic::gaussian_mixture(30, 4, 2, 2, 2.0, 3, "t");
        let d2 = pairwise_sq_dists(&ds.x);
        let sigma = fit_sigma(&d2, 4, 1e-8, 200);
        // one more update is a no-op
        let p = transition_from_d2(&d2, sigma);
        let mut acc = 0f64;
        for i in 0..30 {
            for j in 0..30 {
                acc += p.get(i, j) as f64 * d2.get(i, j) as f64;
            }
        }
        let next = (acc / (30.0 * 4.0)).sqrt();
        assert!((next - sigma).abs() / sigma < 1e-5);
    }
}
