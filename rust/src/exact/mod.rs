//! The exact baseline: the full dense transition matrix P of Eq. (3) —
//! O(N²) construction, memory and multiplication (paper Table 1).
//!
//! Two interchangeable backends:
//! - [`ExactModel`] ([`dense`] underneath): pure Rust (the semantic
//!   reference; mirrors `python/compile/kernels/ref.py`). `Send + Sync`,
//!   so it slots into [`crate::core::op::AnyModel`] and the coordinator.
//! - [`XlaExactModel`]: the AOT Pallas/JAX artifacts executed via
//!   [`crate::runtime`] — the L1/L2 compute path. P is kept in padded
//!   form so LP chunks and matvecs run entirely inside compiled XLA
//!   programs. It owns a thread-local PJRT runtime (`!Send` by design),
//!   so it is served single-threaded and stays outside `AnyModel`.

pub mod dense;

use std::rc::Rc;

use anyhow::Result;

use crate::core::error::VdtError;
use crate::core::Matrix;
use crate::core::op::{Backend, ModelCard, TransitionOp};
use crate::runtime::Runtime;

/// Dense exact transition model (pure Rust).
pub struct ExactModel {
    /// N×N row-stochastic P.
    pub p: Matrix,
    sigma: f64,
    /// Geometry name for registry listings.
    div_name: &'static str,
    /// Dataset the model was fitted on (for [`ModelCard::provenance`]).
    provenance: Option<String>,
}

impl ExactModel {
    /// Pure-Rust build: σ fitted by the alternating Eq. (12) scheme over
    /// singleton blocks (i.e. on the dense distance matrix), then P.
    pub fn build_dense(x: &Matrix, sigma: Option<f64>) -> ExactModel {
        let d2 = dense::pairwise_sq_dists(x);
        let sigma = sigma.unwrap_or_else(|| dense::fit_sigma(&d2, x.cols, 1e-6, 100));
        let p = dense::transition_from_d2(&d2, sigma);
        ExactModel { p, sigma, div_name: "sq_euclidean", provenance: None }
    }

    /// Pure-Rust build under an arbitrary Bregman geometry: pairwise
    /// divergences instead of squared distances, same masked-kernel
    /// normalization and σ fit. The Euclidean kind takes the (symmetric,
    /// half-work) [`dense::pairwise_sq_dists`] path and is identical to
    /// [`ExactModel::build_dense`].
    pub fn build_dense_div(
        x: &Matrix,
        sigma: Option<f64>,
        kind: &crate::core::divergence::DivergenceKind,
    ) -> ExactModel {
        if matches!(kind, crate::core::divergence::DivergenceKind::SqEuclidean) {
            return Self::build_dense(x, sigma);
        }
        let div = kind.instantiate(x);
        let d2 = dense::pairwise_divergences(x, div.as_ref());
        let sigma = sigma.unwrap_or_else(|| dense::fit_sigma(&d2, x.cols, 1e-6, 100));
        let p = dense::transition_from_d2(&d2, sigma);
        ExactModel { p, sigma, div_name: div.name(), provenance: None }
    }

    #[inline]
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Record what the model was fitted on (shown in the [`ModelCard`];
    /// the builder sets this from the dataset name).
    pub fn set_provenance(&mut self, name: impl Into<String>) {
        self.provenance = Some(name.into());
    }

    /// Dataset provenance, when recorded.
    pub fn provenance(&self) -> Option<&str> {
        self.provenance.as_deref()
    }

    /// Label propagation T steps with the dense loop. (Kept `Result` for
    /// signature parity with [`XlaExactModel::lp_run`]; the dense path
    /// itself cannot fail.)
    pub fn lp_run(&self, y0: &Matrix, alpha: f32, steps: usize) -> Result<Matrix> {
        let mut y = y0.clone();
        for _ in 0..steps {
            let mut py = self.p.matmul(&y);
            py.scale_add(alpha, 1.0 - alpha, y0);
            y = py;
        }
        Ok(y)
    }
}

impl TransitionOp for ExactModel {
    fn n(&self) -> usize {
        self.p.rows
    }

    fn matvec_into(&self, y: &Matrix, out: &mut Matrix) {
        self.p.matmul_into(y, out);
    }

    fn card(&self) -> ModelCard {
        ModelCard {
            name: String::new(),
            backend: Backend::Exact,
            divergence: self.div_name.to_string(),
            n: self.p.rows,
            params: self.p.rows * self.p.rows.saturating_sub(1),
            sigma: Some(self.sigma),
            provenance: self.provenance.clone(),
            epoch: 0,
            pending_ingest: 0,
            ingested_points: 0,
        }
    }

    /// Dense row copy — `P[i, ·]` verbatim.
    fn transition_row_into(&self, i: usize, out: &mut [f32]) -> Result<(), VdtError> {
        let n = self.p.rows;
        if i >= n {
            return Err(VdtError::ShapeMismatch { what: "row index", expected: n, got: i });
        }
        if out.len() != n {
            return Err(VdtError::ShapeMismatch { what: "row buffer", expected: n, got: out.len() });
        }
        out.copy_from_slice(self.p.row(i));
        Ok(())
    }
}

/// Exact dense model accelerated by the AOT XLA artifacts: P is computed
/// by the compiled transition kernel and kept padded, so LP chunks and
/// matvecs dispatch straight into compiled programs. Falls back to the
/// embedded dense model on any artifact/shape mismatch.
pub struct XlaExactModel {
    /// The unpadded dense model — also the fallback compute path.
    pub dense: ExactModel,
    rt: Rc<Runtime>,
    /// P at the artifact's padded size (kept so lp_chunk/matvec dispatch
    /// without re-padding).
    p_padded: Matrix,
}

impl XlaExactModel {
    /// XLA build: P computed by the AOT transition artifact (Pallas kernel
    /// inside), σ fitted on the Rust side first (cheap relative to the
    /// O(N²·d) kernel evaluation, and identical math). Squared-Euclidean
    /// geometry only — that is what the artifacts are lowered for.
    pub fn build(x: &Matrix, sigma: Option<f64>, rt: Rc<Runtime>) -> Result<XlaExactModel> {
        let sigma = sigma.unwrap_or_else(|| {
            let d2 = dense::pairwise_sq_dists(x);
            dense::fit_sigma(&d2, x.cols, 1e-6, 100)
        });
        let (p_padded, _n_pad) = rt.transition_padded(x, sigma as f32)?;
        let p = p_padded.sliced(x.rows, x.rows);
        Ok(XlaExactModel {
            dense: ExactModel { p, sigma, div_name: "sq_euclidean", provenance: None },
            rt,
            p_padded,
        })
    }

    /// The unpadded N×N row-stochastic P.
    #[inline]
    pub fn p(&self) -> &Matrix {
        &self.dense.p
    }

    #[inline]
    pub fn sigma(&self) -> f64 {
        self.dense.sigma
    }

    /// See [`ExactModel::set_provenance`].
    pub fn set_provenance(&mut self, name: impl Into<String>) {
        self.dense.set_provenance(name);
    }

    /// Label propagation T steps via the XLA lp_chunk artifact
    /// (⌈T/steps_per_chunk⌉ dispatches), with leftover steps done densely.
    pub fn lp_run(&self, y0: &Matrix, alpha: f32, steps: usize) -> Result<Matrix> {
        let n_pad = self.p_padded.rows;
        let c_pad = self.rt.lp_classes();
        assert!(y0.cols <= c_pad, "more classes than the artifact supports");
        let y0p = y0.padded(n_pad, c_pad);
        let mut y = y0p.clone();
        let chunk = self.rt.lp_chunk_steps();
        let full_chunks = steps / chunk;
        for _ in 0..full_chunks {
            y = self.rt.lp_chunk(&self.p_padded, &y, &y0p, alpha)?;
        }
        // leftover steps (steps % chunk) done densely on the slice
        let mut y_out = y.sliced(self.dense.p.rows, y0.cols);
        for _ in 0..steps % chunk {
            let mut py = self.dense.p.matmul(&y_out);
            py.scale_add(alpha, 1.0 - alpha, y0);
            y_out = py;
        }
        Ok(y_out)
    }
}

impl TransitionOp for XlaExactModel {
    fn n(&self) -> usize {
        self.dense.p.rows
    }

    fn matvec_into(&self, y: &Matrix, out: &mut Matrix) {
        let n = self.dense.p.rows;
        assert_eq!((out.rows, out.cols), (n, y.cols), "output shape mismatch");
        let c_pad = self.rt.lp_classes();
        if y.cols <= c_pad {
            let yp = y.padded(self.p_padded.rows, c_pad);
            if let Ok(full) = self.rt.matvec(&self.p_padded, &yp) {
                for r in 0..n {
                    out.row_mut(r).copy_from_slice(&full.row(r)[..y.cols]);
                }
                return;
            }
        }
        // fall through to dense on any mismatch
        self.dense.p.matmul_into(y, out);
    }

    fn card(&self) -> ModelCard {
        ModelCard { backend: Backend::ExactXla, ..self.dense.card() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn dense_p_is_row_stochastic_zero_diag() {
        let ds = synthetic::two_moons(40, 0.07, 1);
        let m = ExactModel::build_dense(&ds.x, None);
        for (i, s) in m.p.row_sums().iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-4, "row {i}: {s}");
        }
        for i in 0..40 {
            assert_eq!(m.p.get(i, i), 0.0);
        }
        assert!(m.sigma() > 0.0);
        let card = m.card();
        assert_eq!(card.backend, Backend::Exact);
        assert_eq!(card.params, 40 * 39);
    }

    #[test]
    fn lp_run_dense_matches_generic_propagate() {
        let ds = synthetic::two_moons(30, 0.07, 2);
        let m = ExactModel::build_dense(&ds.x, Some(0.5));
        let labeled = crate::labelprop::choose_labeled(&ds.labels, 2, 4, 3);
        let y0 = crate::labelprop::seed_matrix(&ds.labels, &labeled, 2);
        let via_lp_run = m.lp_run(&y0, 0.3, 23).unwrap();
        let via_generic = crate::labelprop::propagate(
            &m,
            &y0,
            &crate::labelprop::LpConfig { alpha: 0.3, steps: 23 },
        );
        assert!(via_lp_run.max_abs_diff(&via_generic) < 1e-4);
    }

    #[test]
    fn matvec_into_matches_matvec() {
        let ds = synthetic::two_moons(25, 0.07, 3);
        let m = ExactModel::build_dense(&ds.x, Some(0.4));
        let y = Matrix::from_fn(25, 3, |r, c| ((r * 3 + c) % 5) as f32 - 2.0);
        let want = m.matvec(&y);
        let mut out = Matrix::from_fn(25, 3, |_, _| 7.0); // pre-filled garbage
        m.matvec_into(&y, &mut out);
        assert_eq!(out.data, want.data);
    }
}
