//! The exact baseline: the full dense transition matrix P of Eq. (3) —
//! O(N²) construction, memory and multiplication (paper Table 1).
//!
//! Two interchangeable backends:
//! - [`dense`]: pure Rust (the semantic reference; mirrors
//!   `python/compile/kernels/ref.py`).
//! - XLA: the AOT Pallas/JAX artifacts executed via [`crate::runtime`] —
//!   the L1/L2 compute path. [`ExactModel::build_xla`] keeps P in padded
//!   form so LP chunks and matvecs run entirely inside compiled XLA
//!   programs.

pub mod dense;

use std::rc::Rc;

use anyhow::Result;

use crate::core::Matrix;
use crate::labelprop::TransitionOp;
use crate::runtime::Runtime;

/// Dense exact transition model.
pub struct ExactModel {
    /// Unpadded N×N row-stochastic P.
    pub p: Matrix,
    sigma: f64,
    /// XLA execution state: runtime + padded P (kept padded so the
    /// lp_chunk/matvec artifacts can be dispatched without re-padding).
    xla: Option<(Rc<Runtime>, Matrix)>,
    backend: &'static str,
    /// Geometry name for registry listings.
    div_name: &'static str,
}

impl ExactModel {
    /// Pure-Rust build: σ fitted by the alternating Eq. (12) scheme over
    /// singleton blocks (i.e. on the dense distance matrix), then P.
    pub fn build_dense(x: &Matrix, sigma: Option<f64>) -> ExactModel {
        let d2 = dense::pairwise_sq_dists(x);
        let sigma = sigma.unwrap_or_else(|| dense::fit_sigma(&d2, x.cols, 1e-6, 100));
        let p = dense::transition_from_d2(&d2, sigma);
        ExactModel { p, sigma, xla: None, backend: "exact-dense", div_name: "sq_euclidean" }
    }

    /// Pure-Rust build under an arbitrary Bregman geometry: pairwise
    /// divergences instead of squared distances, same masked-kernel
    /// normalization and σ fit. The Euclidean kind takes the (symmetric,
    /// half-work) [`dense::pairwise_sq_dists`] path and is identical to
    /// [`ExactModel::build_dense`].
    pub fn build_dense_div(
        x: &Matrix,
        sigma: Option<f64>,
        kind: &crate::core::divergence::DivergenceKind,
    ) -> ExactModel {
        if matches!(kind, crate::core::divergence::DivergenceKind::SqEuclidean) {
            return Self::build_dense(x, sigma);
        }
        let div = kind.instantiate(x);
        let d2 = dense::pairwise_divergences(x, div.as_ref());
        let sigma = sigma.unwrap_or_else(|| dense::fit_sigma(&d2, x.cols, 1e-6, 100));
        let p = dense::transition_from_d2(&d2, sigma);
        ExactModel { p, sigma, xla: None, backend: "exact-dense", div_name: div.name() }
    }

    /// XLA build: P computed by the AOT transition artifact (Pallas kernel
    /// inside), σ fitted on the Rust side first (cheap relative to the
    /// O(N²·d) kernel evaluation, and identical math).
    pub fn build_xla(x: &Matrix, sigma: Option<f64>, rt: Rc<Runtime>) -> Result<ExactModel> {
        let sigma = sigma.unwrap_or_else(|| {
            let d2 = dense::pairwise_sq_dists(x);
            dense::fit_sigma(&d2, x.cols, 1e-6, 100)
        });
        let (p_padded, n_pad) = rt.transition_padded(x, sigma as f32)?;
        let p = p_padded.sliced(x.rows, x.rows);
        let _ = n_pad;
        Ok(ExactModel {
            p,
            sigma,
            xla: Some((rt, p_padded)),
            backend: "exact-xla",
            div_name: "sq_euclidean",
        })
    }

    #[inline]
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Label propagation T steps via the XLA lp_chunk artifact when
    /// available (⌈T/steps_per_chunk⌉ dispatches), dense loop otherwise.
    pub fn lp_run(&self, y0: &Matrix, alpha: f32, steps: usize) -> Result<Matrix> {
        if let Some((rt, p_pad)) = &self.xla {
            let n_pad = p_pad.rows;
            let c_pad = rt.lp_classes();
            assert!(y0.cols <= c_pad, "more classes than the artifact supports");
            let y0p = y0.padded(n_pad, c_pad);
            let mut y = y0p.clone();
            let chunk = rt.lp_chunk_steps();
            let full_chunks = steps / chunk;
            for _ in 0..full_chunks {
                y = rt.lp_chunk(p_pad, &y, &y0p, alpha)?;
            }
            // leftover steps (steps % chunk) done densely on the slice
            let mut y_out = y.sliced(self.p.rows, y0.cols);
            for _ in 0..steps % chunk {
                let mut py = self.p.matmul(&y_out);
                py.scale_add(alpha, 1.0 - alpha, y0);
                y_out = py;
            }
            Ok(y_out)
        } else {
            let mut y = y0.clone();
            for _ in 0..steps {
                let mut py = self.p.matmul(&y);
                py.scale_add(alpha, 1.0 - alpha, y0);
                y = py;
            }
            Ok(y)
        }
    }
}

impl TransitionOp for ExactModel {
    fn n(&self) -> usize {
        self.p.rows
    }

    fn matvec(&self, y: &Matrix) -> Matrix {
        if let Some((rt, p_pad)) = &self.xla {
            let c_pad = rt.lp_classes();
            if y.cols <= c_pad {
                let yp = y.padded(p_pad.rows, c_pad);
                if let Ok(out) = rt.matvec(p_pad, &yp) {
                    return out.sliced(self.p.rows, y.cols);
                }
            }
            // fall through to dense on any mismatch
        }
        self.p.matmul(y)
    }

    fn name(&self) -> &str {
        self.backend
    }

    fn divergence(&self) -> &str {
        self.div_name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn dense_p_is_row_stochastic_zero_diag() {
        let ds = synthetic::two_moons(40, 0.07, 1);
        let m = ExactModel::build_dense(&ds.x, None);
        for (i, s) in m.p.row_sums().iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-4, "row {i}: {s}");
        }
        for i in 0..40 {
            assert_eq!(m.p.get(i, i), 0.0);
        }
        assert!(m.sigma() > 0.0);
    }

    #[test]
    fn lp_run_dense_matches_generic_propagate() {
        let ds = synthetic::two_moons(30, 0.07, 2);
        let m = ExactModel::build_dense(&ds.x, Some(0.5));
        let labeled = crate::labelprop::choose_labeled(&ds.labels, 2, 4, 3);
        let y0 = crate::labelprop::seed_matrix(&ds.labels, &labeled, 2);
        let via_lp_run = m.lp_run(&y0, 0.3, 23).unwrap();
        let via_generic = crate::labelprop::propagate(
            &m,
            &y0,
            &crate::labelprop::LpConfig { alpha: 0.3, steps: 23 },
        );
        assert!(via_lp_run.max_abs_diff(&via_generic) < 1e-4);
    }
}
