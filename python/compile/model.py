"""L2: the exported JAX compute graphs for the exact baseline.

Two entry points, both built on the L1 Pallas kernels and lowered once by
``aot.py`` to HLO text that the Rust runtime executes via PJRT:

- ``transition_entry(x, sigma)``   -> (P,)           Eq. (3)
- ``lp_chunk_entry(p, y, y0, alpha)`` -> (Y',)       ``LP_CHUNK_STEPS`` x Eq. (15)

Shapes are fixed at lowering time (see ``aot.py``); the Rust side pads:
feature padding with zeros is exact (distances unchanged), row padding uses
far-away sentinel points whose kernel contribution underflows to 0, and the
epsilon guard in the row normalization keeps padded rows finite.

``lp_chunk_entry`` runs ``LP_CHUNK_STEPS`` updates per call via ``lax.scan``
so one PJRT dispatch from Rust amortizes several matmuls; the Rust
coordinator loops chunks to reach the paper's T=500.
"""

import jax
import jax.numpy as jnp

from .kernels import lp_step as lp_kernel
from .kernels import pairwise

# Number of LP updates folded into a single compiled artifact call.
LP_CHUNK_STEPS = 10


def _cpu_tile(n: int) -> int:
    """Tile size for the AOT CPU artifacts.

    On a real TPU the natural BlockSpec is (128, 128) MXU tiles. The CPU
    PJRT that executes these artifacts is xla_extension 0.5.1, whose
    while-loop lowering *copies loop-carried operands every iteration* —
    with a (128,128) grid over N=4096 that is 10k copies of the 64 MiB P
    per lp_chunk (~4 min/chunk, measured; EXPERIMENTS.md §Perf). Large
    tiles shrink the grid to ≤64 steps and make the copy cost negligible.
    The kernel code is identical; only the schedule constant changes per
    target (DESIGN.md §Hardware-Adaptation).
    """
    return min(512, n)


def transition_entry(x: jnp.ndarray, sigma: jnp.ndarray):
    """Row-stochastic transition matrix P (Eq. 3); returns a 1-tuple."""
    n = x.shape[0]
    t = _cpu_tile(n)
    return (pairwise.transition_matrix(x, sigma, tm=t, tn=n),)


def lp_chunk_entry(p: jnp.ndarray, y: jnp.ndarray, y0: jnp.ndarray,
                   alpha: jnp.ndarray):
    """LP_CHUNK_STEPS label-propagation updates (Eq. 15); 1-tuple result."""
    n = y.shape[0]
    t = _cpu_tile(n)

    def body(carry, _):
        # full-K tiles: grid (n/t, 1) — see _cpu_tile
        return lp_kernel.lp_step(p, carry, y0, alpha, tm=t, tk=n), None

    out, _ = jax.lax.scan(body, y, None, length=LP_CHUNK_STEPS)
    return (out,)


def matvec_entry(p: jnp.ndarray, y: jnp.ndarray):
    """Single dense multiplication P @ Y (Fig. 2B exact-model timing)."""
    n = y.shape[0]
    t = _cpu_tile(n)
    return (lp_kernel.lp_step(p, y, jnp.zeros_like(y), jnp.asarray(1.0),
                              tm=t, tk=n),)


def sq_norms_entry(x: jnp.ndarray):
    """Row squared norms — used by the Rust side to derive sentinel padding
    magnitudes and in runtime self-tests. Trivial on purpose: it doubles as
    the smoke-test artifact the runtime loads at startup to validate the
    PJRT round trip."""
    return (jnp.sum(x * x, axis=1),)
