"""AOT compile path: lower the L2 entry points to HLO text artifacts.

Run once by ``make artifacts``; Python never runs on the request path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are lowered at a fixed menu of padded shapes; the Rust runtime
(`rust/src/runtime/artifacts.rs`) picks the smallest artifact that fits and
pads (zero feature-padding is exact; far-away sentinel row-padding
underflows to zero kernel mass). ``manifest.json`` describes the menu.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape menu. d=512 covers every dataset the exact baseline is feasible for
# (SecStr 315, Digit1/USPS 241, alpha 500); C=4 covers the 2-class tasks.
TRANSITION_SIZES = [256, 1024, 4096]
TRANSITION_DIM = 512
LP_SIZES = [256, 1024, 4096]
LP_CLASSES = 4
SMOKE_N, SMOKE_D = 8, 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    def emit(name, kind, fn, specs, **meta):
        path = f"{name}.hlo.txt"
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append({"name": name, "kind": kind, "path": path, **meta})
        print(f"  {name}: {len(text)} chars")

    # PJRT round-trip smoke artifact (loaded by runtime self-test).
    emit(
        f"sq_norms_n{SMOKE_N}_d{SMOKE_D}", "sq_norms", model.sq_norms_entry,
        [_f32(SMOKE_N, SMOKE_D)], n=SMOKE_N, d=SMOKE_D,
    )

    for n in TRANSITION_SIZES:
        emit(
            f"transition_n{n}_d{TRANSITION_DIM}", "transition",
            model.transition_entry,
            [_f32(n, TRANSITION_DIM), _f32()],
            n=n, d=TRANSITION_DIM,
        )

    for n in LP_SIZES:
        emit(
            f"lp_chunk_n{n}_c{LP_CLASSES}", "lp_chunk",
            model.lp_chunk_entry,
            [_f32(n, n), _f32(n, LP_CLASSES), _f32(n, LP_CLASSES), _f32()],
            n=n, c=LP_CLASSES, steps=model.LP_CHUNK_STEPS,
        )
        emit(
            f"matvec_n{n}_c{LP_CLASSES}", "matvec",
            model.matvec_entry,
            [_f32(n, n), _f32(n, LP_CLASSES)],
            n=n, c=LP_CLASSES,
        )

    manifest = {
        "version": 1,
        "lp_chunk_steps": model.LP_CHUNK_STEPS,
        "transition_dim": TRANSITION_DIM,
        "lp_classes": LP_CLASSES,
        "artifacts": entries,
    }
    # JSON for humans/tools…
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # …TSV for the Rust runtime (offline build: no serde_json on that side).
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write(f"version\t1\n")
        f.write(f"lp_chunk_steps\t{model.LP_CHUNK_STEPS}\n")
        f.write(f"transition_dim\t{TRANSITION_DIM}\n")
        f.write(f"lp_classes\t{LP_CLASSES}\n")
        for e in entries:
            f.write(
                "artifact\t{name}\t{kind}\t{path}\t{n}\t{d}\t{c}\t{steps}\n".format(
                    name=e["name"], kind=e["kind"], path=e["path"], n=e["n"],
                    d=e.get("d", 0), c=e.get("c", 0), steps=e.get("steps", 0),
                )
            )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory for *.hlo.txt + manifest.json")
    # Back-compat with `--out path/model.hlo.txt`: use its directory.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    manifest = lower_all(out_dir)
    # The Makefile stamps on this file.
    if args.out:
        with open(args.out, "w") as f:
            f.write(json.dumps({"see": "manifest.json"}))
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
