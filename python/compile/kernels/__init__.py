"""L1: Pallas kernels for the exact-model hot spot + pure-jnp oracles.

- ``pairwise``: tiled masked Gaussian kernel matrix / transition matrix.
- ``lp_step``: tiled dense label-propagation update.
- ``ref``: pure-jnp reference implementations (the correctness contract).
"""

from . import lp_step, pairwise, ref  # noqa: F401
