"""L1 Pallas kernel: tiled dense label-propagation update (Eq. 15).

Y' = alpha * P @ Y + (1 - alpha) * Y0   with P (N, N), Y/Y0 (N, C).

Tiling: the output (TM, C) tile is revisited across the K grid dimension —
the canonical Pallas accumulation pattern. Each step loads a (TM, TK) tile
of P and a (TK, C) tile of Y, contracts on the MXU, and accumulates into
the resident output tile; the first K step seeds the accumulator with
(1 - alpha) * Y0.

  grid = (N/TM, N/TK)          # K iterated innermost (sequential)
  P  : block (TM, TK), index (i, k) -> (i, k)
  Y  : block (TK, C),  index (i, k) -> (k, 0)
  Y0 : block (TM, C),  index (i, k) -> (i, 0)
  out: block (TM, C),  index (i, k) -> (i, 0)   # revisited over k

`interpret=True` as everywhere on this image (see pairwise.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lp_tile(p_ref, y_ref, y0_ref, alpha_ref, out_ref):
    k = pl.program_id(1)
    alpha = alpha_ref[0, 0]

    @pl.when(k == 0)
    def _seed():
        out_ref[...] = ((1.0 - alpha) * y0_ref[...]).astype(out_ref.dtype)

    contrib = jax.lax.dot_general(
        p_ref[...], y_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += (alpha * contrib).astype(out_ref.dtype)


def _pick_tile(n: int, preferred: int) -> int:
    t = min(preferred, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tm", "tk"))
def _lp_step_jit(p, y, y0, alpha, tm, tk):
    n, c = y.shape
    alpha2d = jnp.reshape(alpha.astype(jnp.float32), (1, 1))
    grid = (n // tm, n // tk)
    return pl.pallas_call(
        _lp_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, k: (i, k)),
            pl.BlockSpec((tk, c), lambda i, k: (k, 0)),
            pl.BlockSpec((tm, c), lambda i, k: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, c), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), y.dtype),
        interpret=True,
    )(p, y, y0, alpha2d)


def lp_step(p, y, y0, alpha, *, tm: int = 128, tk: int = 128):
    """One Pallas-tiled LP update. Tile sizes shrink to divisors of N."""
    n = y.shape[0]
    tm = _pick_tile(n, tm)
    tk = _pick_tile(n, tk)
    return _lp_step_jit(p, y, y0, jnp.asarray(alpha), tm, tk)
