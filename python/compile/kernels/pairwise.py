"""L1 Pallas kernel: tiled Gaussian kernel matrix (the exact-model hot spot).

Computes K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2)) with a zeroed diagonal,
tiled over (TM, TN) output blocks. The feature dimension rides along whole
(the paper's datasets have d <= 1280; a (128, 1280) f32 block is 640 KiB,
within a TPU core's ~16 MiB VMEM together with the output tile), and the
inner product is expressed as a single `dot` so on real hardware it maps to
the MXU systolic array; the ||x||^2 terms are cheap VPU work.

BlockSpec schedule (the HBM<->VMEM plan a CUDA version would express with
threadblocks):
  grid = (N/TM, N/TN)
  x rows    : block (TM, d), index (i, j) -> (i, 0)   # reused along j
  x cols    : block (TN, d), index (i, j) -> (j, 0)   # reused along i
  sigma     : (1, 1) scalar block, broadcast
  out       : block (TM, TN), index (i, j) -> (i, j)

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (see DESIGN.md
§Hardware-Adaptation). Correctness vs `ref.py` is enforced by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_tile(x_rows_ref, x_cols_ref, sigma_ref, out_ref, *, tm: int, tn: int):
    """One (TM, TN) tile of the masked Gaussian kernel matrix."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    xr = x_rows_ref[...]  # (TM, d)
    xc = x_cols_ref[...]  # (TN, d)
    sigma = sigma_ref[0, 0]

    # ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b ; the dot is the MXU-shaped op.
    rr = jnp.sum(xr * xr, axis=1, keepdims=True)          # (TM, 1)
    cc = jnp.sum(xc * xc, axis=1, keepdims=True)          # (TN, 1)
    cross = jax.lax.dot_general(
        xr, xc,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (TM, TN)
    d2 = jnp.maximum(rr + cc.T - 2.0 * cross, 0.0)

    k = jnp.exp(-d2 / (2.0 * sigma * sigma))

    # Mask the diagonal of the *global* matrix: this tile covers global rows
    # i*TM.. and cols j*TN.. — zero entries where the global ids coincide.
    row_ids = i * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    col_ids = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    k = jnp.where(row_ids == col_ids, 0.0, k)

    out_ref[...] = k.astype(out_ref.dtype)


def _pick_tile(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (tiles must tile N exactly)."""
    t = min(preferred, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def _masked_kernel_matrix_jit(x, sigma, tm, tn):
    n, d = x.shape
    sigma2d = jnp.reshape(sigma.astype(jnp.float32), (1, 1))
    grid = (n // tm, n // tn)
    return pl.pallas_call(
        functools.partial(_kernel_tile, tm=tm, tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=True,
    )(x, x, sigma2d)


def masked_kernel_matrix(x: jnp.ndarray, sigma, *, tm: int = 128, tn: int = 128):
    """Gaussian kernel matrix with zero diagonal, Pallas-tiled.

    ``tm``/``tn`` are preferred tile sizes; they are shrunk to divisors of N
    so the grid tiles the output exactly (padding is the caller's job — the
    AOT entry points use fixed power-of-two shapes).
    """
    n = x.shape[0]
    tm = _pick_tile(n, tm)
    tn = _pick_tile(n, tn)
    return _masked_kernel_matrix_jit(x, jnp.asarray(sigma), tm, tn)


def transition_matrix(x: jnp.ndarray, sigma, *, tm: int = 128, tn: int = 128):
    """Row-stochastic P of Eq. (3): Pallas kernel matrix + fused row norm.

    The normalization is a row reduction over the full N columns — left to
    XLA (it fuses with the division), while the O(N^2 d) kernel evaluation
    is the Pallas tile above.
    """
    k = masked_kernel_matrix(x, sigma, tm=tm, tn=tn)
    row = jnp.sum(k, axis=1, keepdims=True)
    return k / jnp.maximum(row, jnp.asarray(1e-30, dtype=k.dtype))
