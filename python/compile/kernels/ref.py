"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth for the L1 kernels in this package
(`pairwise.py`, `lp_step.py`) and are swept against them by
``python/tests/``. They are also the semantic contract for the Rust dense
fallback in ``rust/src/exact/dense.rs``: both must produce the same numbers.

Everything here mirrors the paper's equations:
  - Eq. (3): transition probabilities p_ij = k(x_i, m_j) / sum_l k(x_i, m_l)
    with the diagonal excluded (p_ii = 0).
  - Eq. (15): label propagation update Y <- alpha * P Y + (1 - alpha) * Y0.
"""

import jax.numpy as jnp


def pairwise_sq_dists(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared Euclidean distances.

    Uses the expanded form ||a - b||^2 = ||a||^2 + ||b||^2 - 2 a.b so the
    hot loop is a single matmul (the same decomposition the Pallas kernel
    tiles for the MXU). Clamped at zero against cancellation.
    """
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True)
    d2 = xx + yy.T - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def gaussian_kernel_matrix(x: jnp.ndarray, sigma) -> jnp.ndarray:
    """K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2)) with zero diagonal."""
    d2 = pairwise_sq_dists(x, x)
    k = jnp.exp(-d2 / (2.0 * sigma * sigma))
    n = x.shape[0]
    return k * (1.0 - jnp.eye(n, dtype=k.dtype))


def transition_matrix(x: jnp.ndarray, sigma) -> jnp.ndarray:
    """Row-stochastic transition matrix P of Eq. (3), zero diagonal.

    Rows whose kernel mass is ~0 (e.g. padding rows placed far away) are
    guarded with a tiny epsilon instead of dividing by zero; their values
    are irrelevant downstream but must stay finite.
    """
    k = gaussian_kernel_matrix(x, sigma)
    row = jnp.sum(k, axis=1, keepdims=True)
    return k / jnp.maximum(row, jnp.asarray(1e-30, dtype=k.dtype))


def lp_step(p: jnp.ndarray, y: jnp.ndarray, y0: jnp.ndarray, alpha) -> jnp.ndarray:
    """One label-propagation update, Eq. (15)."""
    return alpha * (p @ y) + (1.0 - alpha) * y0


def lp_run(p: jnp.ndarray, y0: jnp.ndarray, alpha, steps: int) -> jnp.ndarray:
    """`steps` label-propagation updates starting from Y = Y0."""
    y = y0
    for _ in range(steps):
        y = lp_step(p, y, y0, alpha)
    return y
