"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (and sigma/alpha magnitudes); fixed-seed numpy
draws keep the suite deterministic per example.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import lp_step as lpk
from compile.kernels import pairwise, ref

RNG = np.random.default_rng


def _data(n, d, seed, scale=1.0):
    return (RNG(seed).standard_normal((n, d)) * scale).astype(np.float32)


# ---------------------------------------------------------------- pairwise

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 96),
    d=st.integers(1, 40),
    sigma=st.floats(0.1, 8.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_kernel_matrix_matches_ref(n, d, sigma, seed):
    x = _data(n, d, seed)
    got = pairwise.masked_kernel_matrix(jnp.asarray(x), sigma, tm=16, tn=16)
    want = ref.gaussian_kernel_matrix(jnp.asarray(x), sigma)
    # tolerance model: f32 summation-order differences give |Δd²| ~ 1e-6,
    # which exp() amplifies to relative error ≈ |Δd²|/(2σ²) — at the σ=0.1
    # strategy floor that is ~5e-5; 2e-4 leaves headroom
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 80),
    d=st.integers(1, 32),
    sigma=st.floats(0.2, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_transition_matrix_matches_ref(n, d, sigma, seed):
    x = _data(n, d, seed)
    got = pairwise.transition_matrix(jnp.asarray(x), sigma, tm=16, tn=16)
    want = ref.transition_matrix(jnp.asarray(x), sigma)
    # small sigma amplifies f32 exp() rounding through the normalization
    # (see the tolerance model in the kernel-matrix test above)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-7)


@pytest.mark.parametrize("n,d", [(7, 3), (32, 8), (50, 5)])
def test_transition_rows_stochastic_zero_diag(n, d):
    x = _data(n, d, seed=n * 101 + d)
    p = np.asarray(pairwise.transition_matrix(jnp.asarray(x), 1.0, tm=8, tn=8))
    np.testing.assert_allclose(p.sum(axis=1), np.ones(n), rtol=1e-5)
    np.testing.assert_allclose(np.diag(p), np.zeros(n), atol=0)
    assert (p >= 0).all()


def test_transition_tile_size_invariance():
    x = _data(48, 6, seed=9)
    a = pairwise.transition_matrix(jnp.asarray(x), 0.7, tm=8, tn=8)
    b = pairwise.transition_matrix(jnp.asarray(x), 0.7, tm=48, tn=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_row_padding_with_far_sentinels_is_inert():
    """Rust pads N up to the artifact size with far-away rows; the real
    block of P must be unchanged and padded columns ~0 for real rows."""
    n, d, pad = 24, 4, 8
    x = _data(n, d, seed=3)
    sentinel = 1e4  # runtime uses max_norm-scaled sentinels; 1e4 sigmas away
    xp = np.concatenate(
        [x, np.full((pad, d), sentinel, dtype=np.float32)], axis=0)
    p_small = np.asarray(pairwise.transition_matrix(jnp.asarray(x), 1.0, tm=8, tn=8))
    p_big = np.asarray(pairwise.transition_matrix(jnp.asarray(xp), 1.0, tm=8, tn=8))
    np.testing.assert_allclose(p_big[:n, :n], p_small, rtol=1e-5, atol=1e-7)
    assert np.abs(p_big[:n, n:]).max() == 0.0
    assert np.isfinite(p_big).all()


def test_feature_zero_padding_is_exact():
    """Exact up to float summation order (the contraction length changes)."""
    n, d = 20, 5
    x = _data(n, d, seed=11)
    xp = np.concatenate([x, np.zeros((n, 11), dtype=np.float32)], axis=1)
    a = pairwise.transition_matrix(jnp.asarray(x), 0.9, tm=4, tn=4)
    b = pairwise.transition_matrix(jnp.asarray(xp), 0.9, tm=4, tn=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=1e-7)


# ---------------------------------------------------------------- lp_step

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 64),
    c=st.integers(1, 6),
    alpha=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_lp_step_matches_ref(n, c, alpha, seed):
    r = RNG(seed)
    p = r.random((n, n)).astype(np.float32)
    p /= p.sum(axis=1, keepdims=True)
    y = r.standard_normal((n, c)).astype(np.float32)
    y0 = r.standard_normal((n, c)).astype(np.float32)
    got = lpk.lp_step(jnp.asarray(p), jnp.asarray(y), jnp.asarray(y0), alpha,
                      tm=16, tk=16)
    want = ref.lp_step(jnp.asarray(p), jnp.asarray(y), jnp.asarray(y0), alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-6)


def test_lp_step_tile_invariance():
    r = RNG(5)
    n, c = 40, 3
    p = r.random((n, n)).astype(np.float32)
    y = r.standard_normal((n, c)).astype(np.float32)
    y0 = r.standard_normal((n, c)).astype(np.float32)
    a = lpk.lp_step(jnp.asarray(p), jnp.asarray(y), jnp.asarray(y0), 0.3, tm=8, tk=8)
    b = lpk.lp_step(jnp.asarray(p), jnp.asarray(y), jnp.asarray(y0), 0.3, tm=40, tk=20)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- dtypes

@pytest.mark.parametrize("dtype,rtol", [
    (jnp.float32, 2e-4),
    (jnp.bfloat16, 5e-2),   # 8-bit mantissa
])
def test_masked_kernel_matrix_dtype_sweep(dtype, rtol):
    """The Pallas tile must work at reduced precision (the MXU's native
    bf16 inputs) — compared against the f32 oracle with dtype-scaled
    tolerance."""
    x32 = _data(40, 8, seed=21, scale=0.8)
    x = jnp.asarray(x32, dtype=dtype)
    got = pairwise.masked_kernel_matrix(x, 1.1, tm=8, tn=8)
    assert got.dtype == dtype
    want = ref.gaussian_kernel_matrix(jnp.asarray(x32), 1.1)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want),
        rtol=rtol, atol=rtol * 0.1,
    )


@pytest.mark.parametrize("dtype,rtol", [
    (jnp.float32, 2e-5),
    (jnp.bfloat16, 5e-2),
])
def test_lp_step_dtype_sweep(dtype, rtol):
    r = RNG(31)
    n, c = 32, 3
    p32 = r.random((n, n)).astype(np.float32)
    p32 /= p32.sum(axis=1, keepdims=True)
    y32 = r.standard_normal((n, c)).astype(np.float32)
    got = lpk.lp_step(
        jnp.asarray(p32, dtype=dtype), jnp.asarray(y32, dtype=dtype),
        jnp.asarray(y32, dtype=dtype), 0.2, tm=8, tk=8)
    assert got.dtype == dtype
    want = ref.lp_step(jnp.asarray(p32), jnp.asarray(y32), jnp.asarray(y32), 0.2)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want),
        rtol=rtol, atol=rtol * 0.1,
    )
