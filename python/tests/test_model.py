"""L2 entry-point tests: exported graphs match composed references, and the
AOT lowering produces loadable HLO text."""

import json
import os
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng


def test_transition_entry_matches_ref():
    x = RNG(0).standard_normal((32, 8)).astype(np.float32)
    (p,) = model.transition_entry(jnp.asarray(x), jnp.asarray(1.3))
    want = ref.transition_matrix(jnp.asarray(x), 1.3)
    np.testing.assert_allclose(np.asarray(p), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_lp_chunk_entry_equals_unrolled_ref():
    r = RNG(1)
    n, c = 24, 4
    p = r.random((n, n)).astype(np.float32)
    p /= p.sum(axis=1, keepdims=True)
    y0 = np.zeros((n, c), dtype=np.float32)
    y0[np.arange(n), r.integers(0, c, n)] = 1.0
    (got,) = model.lp_chunk_entry(
        jnp.asarray(p), jnp.asarray(y0), jnp.asarray(y0), jnp.asarray(0.01))
    want = ref.lp_run(jnp.asarray(p), jnp.asarray(y0), 0.01,
                      model.LP_CHUNK_STEPS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-6)


def test_lp_fixed_point_structure():
    """With alpha<1 LP converges to (1-a)(I - aP)^{-1} Y0; check the chunk
    iterates move toward it."""
    r = RNG(2)
    n, c = 16, 2
    p = r.random((n, n)).astype(np.float64)
    np.fill_diagonal(p, 0.0)
    p /= p.sum(axis=1, keepdims=True)
    y0 = np.zeros((n, c))
    y0[np.arange(n), r.integers(0, c, n)] = 1.0
    a = 0.2
    fix = (1 - a) * np.linalg.solve(np.eye(n) - a * p, y0)
    y = jnp.asarray(y0, dtype=jnp.float32)
    p32, y032 = jnp.asarray(p, dtype=jnp.float32), jnp.asarray(y0, dtype=jnp.float32)
    prev_err = np.inf
    for _ in range(5):
        (y,) = model.lp_chunk_entry(p32, y, y032, jnp.asarray(a))
        err = np.abs(np.asarray(y) - fix).max()
        assert err <= prev_err + 1e-7
        prev_err = err
    assert prev_err < 1e-5


def test_aot_lowering_emits_parsable_hlo(tmp_path=None):
    """Smoke artifact lowers to nonempty HLO text with an ENTRY block and
    the manifest indexes every file."""
    d = tempfile.mkdtemp()
    # Temporarily shrink the menu so the test is fast.
    old = (aot.TRANSITION_SIZES, aot.LP_SIZES)
    aot.TRANSITION_SIZES, aot.LP_SIZES = [], []
    try:
        manifest = aot.lower_all(d)
    finally:
        aot.TRANSITION_SIZES, aot.LP_SIZES = old
    assert manifest["artifacts"], "no artifacts emitted"
    for ent in manifest["artifacts"]:
        text = open(os.path.join(d, ent["path"])).read()
        assert "ENTRY" in text and len(text) > 100
    m2 = json.load(open(os.path.join(d, "manifest.json")))
    assert m2["lp_chunk_steps"] == model.LP_CHUNK_STEPS
