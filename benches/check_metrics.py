#!/usr/bin/env python3
"""Lint a Prometheus text-exposition dump (the `GET /metrics` body).

Usage: check_metrics.py <metrics.txt>   (or `-` / no arg for stdin)

Checks the contract `core::obs` promises scrapers, with stdlib only:

- every sample belongs to a family announced by a `# TYPE` line, and
  every `# TYPE` is paired with a `# HELP` (declared at most once each);
- metric and label names are legal (`[a-zA-Z_:][a-zA-Z0-9_:]*` /
  `[a-zA-Z_][a-zA-Z0-9_]*`), label values use only the `\\\\`, `\\"`,
  `\\n` escapes, and sample values parse as floats;
- no duplicate (name, labelset) sample;
- histogram families are complete per labelset: a `_bucket` series with
  strictly-parsing `le` bounds ending at `le="+Inf"`, cumulative counts
  that never decrease, plus `_sum` and `_count`, with the `+Inf` bucket
  equal to `_count`;
- counter and gauge families carry no `_bucket`/`le` samples.

A `# TYPE` with zero samples is fine (a family can be idle at scrape
time). Exit status: 0 = clean, 1 = violations (each printed).
"""

import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)$")
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def valid_escapes(value):
    """Only `\\\\`, `\\"`, `\\n` may follow a backslash (a regex lookahead
    can't tell the second half of an escaped backslash from a new escape,
    so scan sequentially)."""
    i = 0
    while i < len(value):
        if value[i] == "\\":
            if i + 1 >= len(value) or value[i + 1] not in '\\"n':
                return False
            i += 2
        else:
            i += 1
    return True


def parse_labels(raw):
    """`k="v",k2="v2"` -> ((k, v), ...), or None on any syntax error."""
    labels = []
    i = 0
    while i < len(raw):
        m = LABEL_RE.match(raw, i)
        if not m:
            return None
        if not valid_escapes(m.group(2)):
            return None
        labels.append((m.group(1), m.group(2)))
        i = m.end()
        if i < len(raw):
            if raw[i] != ",":
                return None
            i += 1
    return tuple(labels)


def base_family(name, families):
    """Histogram series names map back to their declared family."""
    for suffix in ("_bucket", "_sum", "_count"):
        stem = name[: -len(suffix)] if name.endswith(suffix) else None
        if stem and families.get(stem) == "histogram":
            return stem
    return name


def main(argv):
    path = argv[1] if len(argv) > 1 and argv[1] != "-" else None
    text = open(path).read() if path else sys.stdin.read()

    errors = []
    families = {}  # name -> kind (from # TYPE)
    helped = set()  # names with a # HELP
    samples = []  # (family, series name, labels tuple, float value)
    seen = set()  # duplicate (name, labels) detection

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue

        def err(msg):
            errors.append(f"line {lineno}: {msg}: {line!r}")

        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not NAME_RE.match(parts[2]):
                err("malformed HELP")
            elif parts[2] in helped:
                err(f"duplicate HELP for {parts[2]}")
            else:
                helped.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or not NAME_RE.match(parts[2]):
                err("malformed TYPE")
            elif parts[3] not in ("counter", "gauge", "histogram", "summary", "untyped"):
                err(f"unknown kind '{parts[3]}'")
            elif parts[2] in families:
                err(f"duplicate TYPE for {parts[2]}")
            else:
                families[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # free-form comment

        m = SAMPLE_RE.match(line)
        if not m:
            err("unparseable sample")
            continue
        name, raw_labels, raw_value = m.groups()
        labels = parse_labels(raw_labels) if raw_labels is not None else ()
        if labels is None:
            err("bad label syntax or escape")
            continue
        try:
            value = float(raw_value)
        except ValueError:
            err(f"non-numeric value '{raw_value}'")
            continue
        if (name, labels) in seen:
            err("duplicate sample (same name and labels)")
            continue
        seen.add((name, labels))

        family = base_family(name, families)
        kind = families.get(family)
        if kind is None:
            err(f"sample for {name} has no preceding # TYPE")
            continue
        if family not in helped:
            err(f"family {family} has # TYPE but no # HELP")
        if kind != "histogram" and (
            name != family or any(k == "le" for k, _ in labels)
        ):
            err(f"{kind} family {family} carries a histogram-style sample")
            continue
        samples.append((family, name, labels, value))

    # ---- histogram completeness per (family, labelset-minus-le) ----
    series = {}
    for family, name, labels, value in samples:
        if families[family] != "histogram":
            continue
        key = (family, tuple(kv for kv in labels if kv[0] != "le"))
        entry = series.setdefault(key, {"buckets": [], "sum": None, "count": None})
        if name.endswith("_bucket"):
            le = dict(labels).get("le")
            if le is None:
                errors.append(f"{family}{dict(key[1])}: _bucket without an le label")
                continue
            bound = float("inf") if le == "+Inf" else None
            if bound is None:
                try:
                    bound = float(le)
                except ValueError:
                    errors.append(f"{family}: unparseable le bound '{le}'")
                    continue
            entry["buckets"].append((bound, value))
        elif name.endswith("_sum"):
            entry["sum"] = value
        elif name.endswith("_count"):
            entry["count"] = value
        else:
            errors.append(f"histogram family {family} has a bare sample '{name}'")

    for (family, labels), entry in sorted(series.items()):
        where = f"{family}{{{','.join(f'{k}={v}' for k, v in labels)}}}"
        buckets = entry["buckets"]
        if not buckets:
            errors.append(f"{where}: no _bucket series")
            continue
        bounds = [b for b, _ in buckets]
        if bounds != sorted(bounds) or len(set(bounds)) != len(bounds):
            errors.append(f"{where}: le bounds not strictly increasing: {bounds}")
        if bounds[-1] != float("inf"):
            errors.append(f"{where}: bucket series does not end at le=\"+Inf\"")
        counts = [c for _, c in buckets]
        if any(counts[i] > counts[i + 1] for i in range(len(counts) - 1)):
            errors.append(f"{where}: cumulative bucket counts decrease: {counts}")
        if entry["sum"] is None:
            errors.append(f"{where}: missing _sum")
        if entry["count"] is None:
            errors.append(f"{where}: missing _count")
        elif bounds[-1] == float("inf") and counts[-1] != entry["count"]:
            errors.append(
                f"{where}: +Inf bucket {counts[-1]} != _count {entry['count']}"
            )

    if errors:
        for e in errors:
            print(f"FAIL: {e}")
        print(f"FAIL: {len(errors)} exposition violation(s)")
        return 1
    hists = sum(1 for k in families.values() if k == "histogram")
    print(
        f"PASS: {len(samples)} samples across {len(families)} families "
        f"({hists} histograms, {len(series)} histogram series) lint clean"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
