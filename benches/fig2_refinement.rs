//! Fig 2E / 2I — time to refine each model to the next level
//! (|B|: kN → (k+1)N for VDT, k → k+1 for fast kNN).

use vdt::core::bench::Runner;
use vdt::data::synthetic;
use vdt::knn::{KnnConfig, KnnGraph};
use vdt::vdt::{VdtConfig, VdtModel};

fn main() {
    let mut r = Runner::from_args();
    for (name, ds) in [
        ("digit1", synthetic::digit1_like(1500, 1)),
        ("usps", synthetic::usps_like(1500, 1)),
    ] {
        println!("# fig2ei_refinement ({name}-like)");
        for k in [3usize, 5] {
            r.bench_with_setup(
                &format!("fig2ei/vdt_to_{k}N/{name}"),
                || {
                    let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
                    if k > 3 {
                        m.refine_to((k - 1) * ds.n());
                    }
                    m
                },
                |mut m| {
                    m.refine_to(k * ds.n());
                    m.num_blocks()
                },
            );
            r.bench_with_setup(
                &format!("fig2ei/knn_to_k{k}/{name}"),
                || KnnGraph::build(&ds.x, &KnnConfig { k: k - 1, ..Default::default() }),
                |mut g| {
                    g.refine_to_k(k);
                    g.num_params()
                },
            );
            if let (Some(v), Some(kn)) = (
                r.mean_of(&format!("fig2ei/vdt_to_{k}N/{name}")),
                r.mean_of(&format!("fig2ei/knn_to_k{k}/{name}")),
            ) {
                println!("# refinement speedup vdt vs knn at level {k} ({name}): {:.1}x", kn / v);
            }
        }
    }
}
