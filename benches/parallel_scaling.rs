//! Serial-vs-parallel scaling for the `core::par` execution layer at
//! serving-relevant sizes (N ≥ 16k by default; `BENCH_N` overrides for
//! smoke runs). Measures the four paths the perf trajectory tracks —
//! tree build, kNN graph construction, VDT refinement, LP sweep — plus
//! the column-blocked matvec, and writes `BENCH_parallel.json` so each
//! run's thread-scaling lands in the repo's perf record.

use vdt::core::bench::Runner;
use vdt::core::par;
use vdt::data::synthetic;
use vdt::knn::{KnnConfig, KnnGraph};
use vdt::labelprop::{self, LpConfig};
use vdt::tree::{build_tree, BuildConfig};
use vdt::vdt::{VdtConfig, VdtModel};

fn env_n(default: usize) -> usize {
    std::env::var("BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run `body` with the thread budget forced to `threads`, restoring after.
fn with_threads<T>(threads: usize, body: impl FnOnce() -> T) -> T {
    let prev = par::set_max_threads(threads);
    let out = body();
    par::set_max_threads(prev);
    out
}

fn main() {
    let n = env_n(16_000);
    let hw_threads = par::max_threads();
    let mut r = Runner::from_args();
    r.budget_secs = 1.0;
    r.max_iters = 5;
    println!("# parallel_scaling: N={n}, thread budget {hw_threads}");

    // ---- tree build ----
    let ds_tree = synthetic::gaussian_mixture(n, 64, 2, 8, 2.0, 1, "bench");
    let serial_cfg = BuildConfig { parallel: false, ..Default::default() };
    let parallel_cfg = BuildConfig::default();
    r.bench(&format!("par/tree_build/serial/N={n}"), || {
        std::hint::black_box(build_tree(&ds_tree.x, &serial_cfg));
    });
    r.bench(&format!("par/tree_build/threads/N={n}"), || {
        std::hint::black_box(build_tree(&ds_tree.x, &parallel_cfg));
    });

    // ---- kNN graph construction ----
    let ds_knn = synthetic::two_moons(n, 0.06, 2);
    r.bench(&format!("par/knn_graph/serial/N={n}"), || {
        std::hint::black_box(KnnGraph::build(
            &ds_knn.x,
            &KnnConfig { k: 4, ..Default::default() },
        ));
    });
    r.bench(&format!("par/knn_graph/threads/N={n}"), || {
        std::hint::black_box(KnnGraph::build(
            &ds_knn.x,
            &KnnConfig { k: 4, parallel: true, ..Default::default() },
        ));
    });

    // ---- refinement 2N -> 6N ----
    let ds_ref = &ds_tree;
    for (label, threads) in [("serial", 1usize), ("threads", hw_threads)] {
        with_threads(threads, || {
            r.bench_with_setup(
                &format!("par/refine_to_6N/{label}/N={n}"),
                || VdtModel::build(&ds_ref.x, &VdtConfig::default()),
                |mut m| {
                    m.refine_to(6 * ds_ref.n());
                    m.num_blocks()
                },
            );
        });
    }

    // ---- LP sweep (8 columns) and matvec ----
    let ds_lp = synthetic::gaussian_mixture(n, 32, 8, 2, 2.2, 3, "bench_lp");
    let mut model = VdtModel::build(&ds_lp.x, &VdtConfig::default());
    model.refine_to(6 * ds_lp.n());
    let labeled = labelprop::choose_labeled(&ds_lp.labels, ds_lp.n_classes, n / 10, 4);
    let y0 = labelprop::seed_matrix(&ds_lp.labels, &labeled, ds_lp.n_classes);
    let lp_cfg = LpConfig { alpha: 0.01, steps: 10 };
    for (label, threads) in [("serial", 1usize), ("threads", hw_threads)] {
        with_threads(threads, || {
            r.bench(&format!("par/lp_sweep_10x8col/{label}/N={n}"), || {
                std::hint::black_box(labelprop::propagate(&model, &y0, &lp_cfg));
            });
            r.bench(&format!("par/matvec_8col/{label}/N={n}"), || {
                std::hint::black_box(model.matvec(&y0));
            });
        });
    }

    // sanity: parallel LP output must equal serial (the equivalence tests
    // pin this; the bench double-checks on the bench shapes)
    let a = with_threads(1, || labelprop::propagate(&model, &y0, &lp_cfg));
    let b = with_threads(hw_threads, || labelprop::propagate(&model, &y0, &lp_cfg));
    assert_eq!(a.data, b.data, "parallel LP diverged from serial");

    // ---- emit BENCH_parallel.json ----
    let pairs = [
        ("tree_build", format!("par/tree_build/serial/N={n}"), format!("par/tree_build/threads/N={n}")),
        ("knn_graph", format!("par/knn_graph/serial/N={n}"), format!("par/knn_graph/threads/N={n}")),
        ("refine_to_6N", format!("par/refine_to_6N/serial/N={n}"), format!("par/refine_to_6N/threads/N={n}")),
        ("lp_sweep", format!("par/lp_sweep_10x8col/serial/N={n}"), format!("par/lp_sweep_10x8col/threads/N={n}")),
        ("matvec", format!("par/matvec_8col/serial/N={n}"), format!("par/matvec_8col/threads/N={n}")),
    ];
    if pairs.iter().any(|(_, s, t)| r.mean_of(s).is_none() || r.mean_of(t).is_none()) {
        println!("# filtered run: skipping BENCH_parallel.json (needs all pairs)");
        return;
    }
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"bench\": \"parallel_scaling\",\n  \"n\": {n},\n"));
    json.push_str(&format!("  \"threads\": {hw_threads},\n  \"paths\": [\n"));
    let mut wins_2x = 0usize;
    for (i, (key, s_name, t_name)) in pairs.iter().enumerate() {
        let s = r.mean_of(s_name).expect("checked above");
        let t = r.mean_of(t_name).expect("checked above");
        let speedup = s / t;
        if speedup >= 2.0 {
            wins_2x += 1;
        }
        json.push_str(&format!(
            "    {{\"path\": \"{key}\", \"serial_ms\": {s:.3}, \"parallel_ms\": {t:.3}, \"speedup\": {speedup:.3}}}{}\n",
            if i + 1 < pairs.len() { "," } else { "" }
        ));
        println!("# {key}: serial {s:.1} ms, parallel {t:.1} ms -> {speedup:.2}x");
    }
    json.push_str(&format!("  ],\n  \"paths_at_or_above_2x\": {wins_2x}\n}}\n"));
    if let Err(e) = std::fs::write("BENCH_parallel.json", &json) {
        eprintln!("warn: could not write BENCH_parallel.json: {e}");
    } else {
        println!("# wrote BENCH_parallel.json ({wins_2x} path(s) at >= 2x)");
    }
}
