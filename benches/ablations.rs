//! Ablations on the design choices DESIGN.md calls out:
//!  - anchor tree vs divisive-only construction,
//!  - serial vs threaded kNN search,
//!  - multi-column vs column-at-a-time matvec (the coordinator's fusion),
//!  - σ alternation vs fixed bandwidth (construction share),
//!  - Table-1 empirical scaling exponents.

use vdt::core::bench::Runner;
use vdt::core::metrics::loglog_slope;
use vdt::data::synthetic;
use vdt::knn::{KnnConfig, KnnGraph};
use vdt::labelprop::one_hot_labels;
use vdt::tree::{build_tree, BuildConfig};
use vdt::vdt::{VdtConfig, VdtModel};

fn main() {
    let mut r = Runner::from_args();

    println!("# ablation: tree construction strategy");
    let ds = synthetic::secstr_like(4000, 1);
    r.bench("ablation/tree_build/anchors_default", || {
        std::hint::black_box(build_tree(&ds.x, &BuildConfig::default()));
    });
    r.bench("ablation/tree_build/divisive_only", || {
        std::hint::black_box(build_tree(&ds.x, &BuildConfig { divisive_threshold: usize::MAX, ..Default::default() }));
    });

    println!("\n# ablation: kNN search parallelism");
    let ds2 = synthetic::secstr_like(3000, 1);
    for (name, par) in [("serial", false), ("threads", true)] {
        r.bench(&format!("ablation/knn_build/{name}"), || {
            std::hint::black_box(KnnGraph::build(
                &ds2.x,
                &KnnConfig { k: 4, parallel: par, ..Default::default() },
            ));
        });
    }

    println!("\n# ablation: matvec column fusion");
    let ds3 = synthetic::digit1_like(1500, 1);
    let mut m = VdtModel::build(&ds3.x, &VdtConfig::default());
    m.refine_to(6 * ds3.n());
    let y8 = one_hot_labels(&ds3.labels.iter().map(|&l| l % 8).collect::<Vec<_>>(), 8);
    r.bench("ablation/matvec/fused_8_columns", || {
        std::hint::black_box(m.matvec(&y8));
    });
    r.bench("ablation/matvec/one_column_x8", || {
        for col in 0..8 {
            let y1 = vdt::Matrix::from_fn(ds3.n(), 1, |row, _| y8.get(row, col));
            std::hint::black_box(m.matvec(&y1));
        }
    });
    if let (Some(f), Some(s)) = (
        r.mean_of("ablation/matvec/fused_8_columns"),
        r.mean_of("ablation/matvec/one_column_x8"),
    ) {
        println!("# fusion speedup for 8 columns: {:.2}x", s / f);
    }

    println!("\n# ablation: sigma fitting cost");
    for (name, fixed) in [("fixed_sigma", true), ("alternating", false)] {
        r.bench(&format!("ablation/sigma_fit/{name}"), || {
            let cfg = VdtConfig {
                sigma: if fixed { Some(1.0) } else { None },
                ..Default::default()
            };
            std::hint::black_box(VdtModel::build(&ds3.x, &cfg));
        });
    }

    println!("\n# table1: empirical scaling exponents (see also `vdt exp table1`)");
    let sizes = [500usize, 1000, 2000, 4000];
    let mut construct = Vec::new();
    let mut matvec = Vec::new();
    for &n in &sizes {
        let d = synthetic::secstr_like(n, 3);
        let t = std::time::Instant::now();
        let v = VdtModel::build(&d.x, &VdtConfig::default());
        construct.push(t.elapsed().as_secs_f64());
        let y = one_hot_labels(&d.labels, d.n_classes);
        let _ = v.matvec(&y);
        let t = std::time::Instant::now();
        for _ in 0..5 {
            std::hint::black_box(v.matvec(&y));
        }
        matvec.push(t.elapsed().as_secs_f64() / 5.0);
    }
    let ns: Vec<f64> = sizes.iter().map(|&n| n as f64).collect();
    println!(
        "# vdt construction slope = {:.2} (paper ~1.5+log), matvec slope = {:.2} (paper 1.0)",
        loglog_slope(&ns, &construct),
        loglog_slope(&ns, &matvec)
    );
}
