//! Fig 2B — one multiplication (P·Y) across the three representations,
//! plus the matvec-cost-vs-|B| series showing the O(|B|) law. Memory
//! shares Table 1's complexity column with multiplication, so this bench
//! doubles as the memory comparison. A final section times the
//! column-blocked matvec and a 10-step LP sweep serial vs parallel (the
//! `core::par` thread-scaling record lives in `benches/parallel_scaling.rs`
//! / `BENCH_parallel.json`).

use vdt::core::bench::Runner;
use vdt::core::op::TransitionOp;
use vdt::core::par;
use vdt::data::synthetic;
use vdt::exact::ExactModel;
use vdt::knn::{KnnConfig, KnnGraph};
use vdt::labelprop::{self, one_hot_labels, LpConfig};
use vdt::vdt::{VdtConfig, VdtModel};

fn main() {
    let mut r = Runner::from_args();
    println!("# fig2b_multiplication (secstr-like)");
    for &n in &[500usize, 1000, 2000, 4000] {
        let ds = synthetic::secstr_like(n, 1);
        let y = one_hot_labels(&ds.labels, ds.n_classes);

        let vdt = VdtModel::build(&ds.x, &VdtConfig::default());
        r.bench(&format!("fig2b/vdt_coarsest/N={n}"), || {
            std::hint::black_box(vdt.matvec(&y));
        });

        let knn = KnnGraph::build(&ds.x, &KnnConfig { k: 2, ..Default::default() });
        r.bench(&format!("fig2b/fast_knn_k2/N={n}"), || {
            std::hint::black_box(knn.matvec(&y));
        });

        if n <= 2000 {
            let exact = ExactModel::build_dense(&ds.x, None);
            r.bench(&format!("fig2b/exact_dense/N={n}"), || {
                std::hint::black_box(exact.matvec(&y));
            });
        }
    }
    if let (Some(v), Some(e)) = (
        r.mean_of("fig2b/vdt_coarsest/N=2000"),
        r.mean_of("fig2b/exact_dense/N=2000"),
    ) {
        println!("# speedup vdt vs exact matvec at N=2000: {:.1}x", e / v);
    }

    println!("\n# fig2b matvec cost vs refinement level (O(|B|) law)");
    let ds = synthetic::digit1_like(1500, 1);
    let y = one_hot_labels(&ds.labels, ds.n_classes);
    let mut vdt = VdtModel::build(&ds.x, &VdtConfig::default());
    for k in [2usize, 4, 8] {
        vdt.refine_to(k * ds.n());
        r.bench(&format!("fig2b/vdt_matvec/B={k}N"), || {
            std::hint::black_box(vdt.matvec(&y));
        });
    }

    println!("\n# fig2b serial vs parallel matvec / LP sweep (core::par)");
    let hw = par::max_threads();
    let dsp = synthetic::gaussian_mixture(6000, 32, 8, 2, 2.2, 1, "fig2b_par");
    let mut vdtp = VdtModel::build(&dsp.x, &VdtConfig::default());
    vdtp.refine_to(6 * dsp.n());
    let yp = one_hot_labels(&dsp.labels, dsp.n_classes);
    let lp_cfg = LpConfig { alpha: 0.01, steps: 10 };
    for (label, threads) in [("serial", 1usize), ("threads", hw)] {
        let prev = par::set_max_threads(threads);
        r.bench(&format!("fig2b/vdt_matvec_8col/{label}/N=6000"), || {
            std::hint::black_box(vdtp.matvec(&yp));
        });
        r.bench(&format!("fig2b/lp_sweep_10step/{label}/N=6000"), || {
            std::hint::black_box(labelprop::propagate(&vdtp, &yp, &lp_cfg));
        });
        par::set_max_threads(prev);
    }
    if let (Some(s), Some(t)) = (
        r.mean_of("fig2b/vdt_matvec_8col/serial/N=6000"),
        r.mean_of("fig2b/vdt_matvec_8col/threads/N=6000"),
    ) {
        println!("# matvec parallel speedup at N=6000, C=8: {:.2}x ({hw} threads)", s / t);
    }
    if let (Some(s), Some(t)) = (
        r.mean_of("fig2b/lp_sweep_10step/serial/N=6000"),
        r.mean_of("fig2b/lp_sweep_10step/threads/N=6000"),
    ) {
        println!("# LP-sweep parallel speedup at N=6000, C=8: {:.2}x ({hw} threads)", s / t);
    }
}
