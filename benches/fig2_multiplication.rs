//! Fig 2B — one multiplication (P·Y) across the three representations,
//! plus the matvec-cost-vs-|B| series showing the O(|B|) law. Memory
//! shares Table 1's complexity column with multiplication, so this bench
//! doubles as the memory comparison. A second section times the
//! column-blocked matvec and a 10-step LP sweep serial vs parallel (the
//! `core::par` thread-scaling record lives in `benches/parallel_scaling.rs`
//! / `BENCH_parallel.json`). The final `mrhs/` section measures the
//! raw-speed levers of the fused hot path — one multi-RHS traversal vs C
//! per-column traversals, scalar vs runtime-detected SIMD lanes — at
//! BENCH_N (default 8000) and emits `BENCH_matvec.json` for the CI bench
//! gate. A name filter (`cargo bench --bench fig2_multiplication -- mrhs`)
//! skips the other sections' model builds entirely.

use vdt::core::bench::Runner;
use vdt::core::op::TransitionOp;
use vdt::core::par;
use vdt::core::simd::{self, SimdMode};
use vdt::data::synthetic;
use vdt::exact::ExactModel;
use vdt::knn::{KnnConfig, KnnGraph};
use vdt::labelprop::{self, one_hot_labels, LpConfig};
use vdt::vdt::{VdtConfig, VdtModel};
use vdt::Matrix;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let mut r = Runner::from_args();
    // Runner filters per-bench by substring; sections gate their (much
    // more expensive) model builds on the same argument so a filtered run
    // doesn't pay for setup it will never time. A section runs when there
    // is no filter or the filter string overlaps the section prefix.
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let want = |section: &str| {
        filter
            .as_ref()
            .map_or(true, |f| f.contains(section) || section.contains(f.as_str()))
    };

    if want("fig2b") {
        println!("# fig2b_multiplication (secstr-like)");
        for &n in &[500usize, 1000, 2000, 4000] {
            let ds = synthetic::secstr_like(n, 1);
            let y = one_hot_labels(&ds.labels, ds.n_classes);

            let vdt = VdtModel::build(&ds.x, &VdtConfig::default());
            r.bench(&format!("fig2b/vdt_coarsest/N={n}"), || {
                std::hint::black_box(vdt.matvec(&y));
            });

            let knn = KnnGraph::build(&ds.x, &KnnConfig { k: 2, ..Default::default() });
            r.bench(&format!("fig2b/fast_knn_k2/N={n}"), || {
                std::hint::black_box(knn.matvec(&y));
            });

            if n <= 2000 {
                let exact = ExactModel::build_dense(&ds.x, None);
                r.bench(&format!("fig2b/exact_dense/N={n}"), || {
                    std::hint::black_box(exact.matvec(&y));
                });
            }
        }
        if let (Some(v), Some(e)) = (
            r.mean_of("fig2b/vdt_coarsest/N=2000"),
            r.mean_of("fig2b/exact_dense/N=2000"),
        ) {
            println!("# speedup vdt vs exact matvec at N=2000: {:.1}x", e / v);
        }

        println!("\n# fig2b matvec cost vs refinement level (O(|B|) law)");
        let ds = synthetic::digit1_like(1500, 1);
        let y = one_hot_labels(&ds.labels, ds.n_classes);
        let mut vdt = VdtModel::build(&ds.x, &VdtConfig::default());
        for k in [2usize, 4, 8] {
            vdt.refine_to(k * ds.n());
            r.bench(&format!("fig2b/vdt_matvec/B={k}N"), || {
                std::hint::black_box(vdt.matvec(&y));
            });
        }

        println!("\n# fig2b serial vs parallel matvec / LP sweep (core::par)");
        let hw = par::max_threads();
        let dsp = synthetic::gaussian_mixture(6000, 32, 8, 2, 2.2, 1, "fig2b_par");
        let mut vdtp = VdtModel::build(&dsp.x, &VdtConfig::default());
        vdtp.refine_to(6 * dsp.n());
        let yp = one_hot_labels(&dsp.labels, dsp.n_classes);
        let lp_cfg = LpConfig { alpha: 0.01, steps: 10 };
        for (label, threads) in [("serial", 1usize), ("threads", hw)] {
            let prev = par::set_max_threads(threads);
            r.bench(&format!("fig2b/vdt_matvec_8col/{label}/N=6000"), || {
                std::hint::black_box(vdtp.matvec(&yp));
            });
            r.bench(&format!("fig2b/lp_sweep_10step/{label}/N=6000"), || {
                std::hint::black_box(labelprop::propagate(&vdtp, &yp, &lp_cfg));
            });
            par::set_max_threads(prev);
        }
        if let (Some(s), Some(t)) = (
            r.mean_of("fig2b/vdt_matvec_8col/serial/N=6000"),
            r.mean_of("fig2b/vdt_matvec_8col/threads/N=6000"),
        ) {
            println!("# matvec parallel speedup at N=6000, C=8: {:.2}x ({hw} threads)", s / t);
        }
        if let (Some(s), Some(t)) = (
            r.mean_of("fig2b/lp_sweep_10step/serial/N=6000"),
            r.mean_of("fig2b/lp_sweep_10step/threads/N=6000"),
        ) {
            println!("# LP-sweep parallel speedup at N=6000, C=8: {:.2}x ({hw} threads)", s / t);
        }
    }

    // ---- multi-RHS fused sweep × SIMD tier (BENCH_matvec.json) ----
    //
    // The two raw-speed levers of the fused hot path, measured
    // independently and together:
    //   percol   — C separate single-column `matmul_into` calls (the old
    //              cost model: one CollectUp/DistributeDown per column)
    //   multirhs — one C-column `matmul_into` (one traversal, all columns)
    // each under VDT_SIMD=0 (scalar) and the default runtime-detected
    // lanes. All four variants are asserted bit-identical before timing.
    let nm = env_usize("BENCH_N", 8000);
    let widths = [8usize, 32];
    if want("mrhs") {
        println!("\n# mrhs: multi-RHS fused sweep x SIMD tier (N={nm}, |B|=6N)");
        let dsm = synthetic::gaussian_mixture(nm, 32, 8, 2, 2.2, 2, "fig2b_mrhs");
        let mut vdtm = VdtModel::build(&dsm.x, &VdtConfig::default());
        vdtm.refine_to(6 * nm);
        println!("# simd lanes detected: {}", simd::active_lanes());
        for &c in &widths {
            let y = Matrix::from_fn(nm, c, |row, k| {
                (((row * 29 + k * 13) % 17) as f32 - 8.0) * 0.11
            });
            let cols: Vec<Matrix> =
                (0..c).map(|k| Matrix::from_fn(nm, 1, |row, _| y.get(row, k))).collect();

            // bit-parity gate: fused == stacked per-column, SIMD == scalar
            let prev = simd::set_simd_mode(SimdMode::Scalar);
            let reference = vdtm.matmul(&y);
            for (k, yk) in cols.iter().enumerate() {
                let alone = vdtm.matmul(yk);
                for row in 0..nm {
                    assert_eq!(
                        alone.get(row, 0).to_bits(),
                        reference.get(row, k).to_bits(),
                        "C={c} col={k}: multi-RHS diverged from per-column"
                    );
                }
            }
            simd::set_simd_mode(SimdMode::Auto);
            assert_eq!(
                vdtm.matmul(&y).data,
                reference.data,
                "C={c}: SIMD tier is not bit-exact vs scalar"
            );
            simd::set_simd_mode(prev);

            let mut out_one = Matrix::zeros(nm, 1);
            let mut out_all = Matrix::zeros(nm, c);
            for (label, mode) in [("scalar", SimdMode::Scalar), ("simd", SimdMode::Auto)] {
                let prev = simd::set_simd_mode(mode);
                r.bench(&format!("mrhs/C={c}/percol/{label}"), || {
                    for yk in &cols {
                        vdtm.matmul_into(yk, &mut out_one);
                        std::hint::black_box(&out_one);
                    }
                });
                r.bench(&format!("mrhs/C={c}/multirhs/{label}"), || {
                    vdtm.matmul_into(&y, &mut out_all);
                    std::hint::black_box(&out_all);
                });
                simd::set_simd_mode(prev);
            }
            if let (Some(p), Some(m)) = (
                r.mean_of(&format!("mrhs/C={c}/percol/simd")),
                r.mean_of(&format!("mrhs/C={c}/multirhs/simd")),
            ) {
                println!("# multi-RHS speedup at N={nm}, C={c} (simd): {:.2}x", p / m);
            }
            if let (Some(s), Some(v)) = (
                r.mean_of(&format!("mrhs/C={c}/multirhs/scalar")),
                r.mean_of(&format!("mrhs/C={c}/multirhs/simd")),
            ) {
                println!("# SIMD speedup at N={nm}, C={c} (multirhs): {:.2}x", s / v);
            }
        }
    }

    // ---- emit BENCH_matvec.json ----
    // schema matches benches/check_regression.py: entries under "paths",
    // keyed by "path", gated timing in "ms"
    let mut entries: Vec<(String, f64)> = Vec::new();
    for &c in &widths {
        for kind in ["percol", "multirhs"] {
            for tier in ["scalar", "simd"] {
                let name = format!("mrhs/C={c}/{kind}/{tier}");
                if let Some(ms) = r.mean_of(&name) {
                    entries.push((name, ms));
                }
            }
        }
    }
    if entries.is_empty() {
        println!("# BENCH_matvec.json skipped (mrhs section filtered out)");
        return;
    }
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"matvec_multirhs\",\n  \"n\": {nm},\n  \"lanes\": \"{}\",\n  \"paths\": [\n",
        simd::active_lanes()
    ));
    for (i, (name, ms)) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"path\": \"{name}\", \"ms\": {ms:.3}}}{}\n",
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_matvec.json", &json) {
        eprintln!("warn: could not write BENCH_matvec.json: {e}");
    } else {
        println!("# wrote BENCH_matvec.json ({} timings)", entries.len());
    }
}
