//! Fig 2A / 2D / 2H — construction time: exact vs fast-kNN(k=2) vs
//! VariationalDT(coarsest), over secstr-like samples of growing N and the
//! two 1500-point refinement datasets.
//!
//! Offline build: timing loops use the in-tree harness
//! (`vdt::core::bench::Runner`); `cargo bench` runs this `main`.

use vdt::core::bench::Runner;
use vdt::data::synthetic;
use vdt::exact::ExactModel;
use vdt::knn::{KnnConfig, KnnGraph};
use vdt::vdt::{VdtConfig, VdtModel};

fn main() {
    let mut r = Runner::from_args();
    println!("# fig2a_construction (secstr-like)");
    for &n in &[500usize, 1000, 2000] {
        let ds = synthetic::secstr_like(n, 1);
        r.bench(&format!("fig2a/vdt_coarsest/N={n}"), || {
            std::hint::black_box(VdtModel::build(&ds.x, &VdtConfig::default()));
        });
        r.bench(&format!("fig2a/fast_knn_k2/N={n}"), || {
            std::hint::black_box(KnnGraph::build(&ds.x, &KnnConfig { k: 2, ..Default::default() }));
        });
        if n <= 1000 {
            r.bench(&format!("fig2a/exact_dense/N={n}"), || {
                std::hint::black_box(ExactModel::build_dense(&ds.x, None));
            });
        }
    }
    // headline ratio at N=1000 (the paper claims orders of magnitude)
    if let (Some(v), Some(e)) = (
        r.mean_of("fig2a/vdt_coarsest/N=1000"),
        r.mean_of("fig2a/exact_dense/N=1000"),
    ) {
        println!("# speedup vdt vs exact at N=1000: {:.1}x", e / v);
    }
    if let (Some(v), Some(k)) = (
        r.mean_of("fig2a/vdt_coarsest/N=2000"),
        r.mean_of("fig2a/fast_knn_k2/N=2000"),
    ) {
        println!("# speedup vdt vs fast-knn at N=2000: {:.1}x", k / v);
    }

    println!("\n# fig2dh_construction_1500 (digit1/usps-like)");
    for (name, ds) in [
        ("digit1", synthetic::digit1_like(1500, 1)),
        ("usps", synthetic::usps_like(1500, 1)),
    ] {
        r.bench(&format!("fig2dh/vdt_coarsest/{name}"), || {
            std::hint::black_box(VdtModel::build(&ds.x, &VdtConfig::default()));
        });
        r.bench(&format!("fig2dh/fast_knn_k2/{name}"), || {
            std::hint::black_box(KnnGraph::build(&ds.x, &KnnConfig { k: 2, ..Default::default() }));
        });
        r.bench(&format!("fig2dh/exact_dense/{name}"), || {
            std::hint::black_box(ExactModel::build_dense(&ds.x, None));
        });
    }
}
