//! HTTP serving throughput: batched vs unbatched, concurrency 32.
//!
//! Spins the full serving stack twice over one fitted VDT model
//! (BENCH_N, default 8000 points, |B| = 6N):
//!
//! - **batched**: default coordinator (burst fusion on) + the server's
//!   micro-batcher (1 ms window, 64-request cap) — concurrent same-model
//!   requests coalesce into one fused sweep;
//! - **unbatched**: no coalescing anywhere — the coordinator is spawned
//!   with fusion off and a zero burst window, the server calls it once
//!   per request. This is the true per-request baseline the batching
//!   subsystem exists to beat.
//!
//! 32 keep-alive clients hammer `POST matvec` (one column each), `POST
//! matvec` with an 8-column Y (the multi-RHS request shape — fused bursts
//! execute as one true multi-RHS sweep downstream), and `POST query` (one
//! out-of-sample point each); we record req/s and p50/p99 latency per
//! endpoint per mode and emit `BENCH_http.json` (consumed by the CI bench
//! job next to `BENCH_parallel.json` / `BENCH_serve.json`).
//!
//! Correctness gate: a served matvec response must decode to the exact
//! bits of a direct `TransitionOp::matvec` — a throughput number from a
//! server that rounds floats would be worthless.
//!
//! After the mode comparison, a **keep-alive concurrency sweep** opens
//! `BENCH_HTTP_CONNS` (default 1024, clamped to the fd budget)
//! simultaneous keep-alive connections against the event loop at the
//! DEFAULT compute-pool size — the connection ceiling is `max_conns`
//! now, not the worker count — and hammers matvec over all of them with
//! sampled bit-parity. Emitted as `batched/matvec@c{conns}` entries in
//! `BENCH_http.json`.
//!
//! Finally, the **observability overhead** entries: `/metrics` and
//! `/stats` scrape latency against the traffic-populated registry
//! (`obs/metrics_scrape`, `obs/stats_scrape`) and the raw cost of a
//! 4M-observation histogram hot loop (`obs/observe_x4m`) — the always-on
//! per-request instrumentation cost the regression gate watches.

use std::sync::Arc;
use std::time::{Duration, Instant};

use vdt::coordinator::{Coordinator, CoordinatorConfig, CoordinatorHandle};
use vdt::core::json::Json;
use vdt::data::synthetic;
use vdt::runtime::server::client::HttpClient;
use vdt::runtime::server::{matrix_body, matrix_from_json, Server, ServerConfig};
use vdt::vdt::{VdtConfig, VdtModel};
use vdt::Matrix;

const CONCURRENCY: usize = 32;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[derive(Clone, Copy)]
struct ModeResult {
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run `rounds` requests from each of [`CONCURRENCY`] keep-alive clients
/// against `path`, bodies produced per (client, round). Returns req/s and
/// latency percentiles.
fn hammer(
    addr: std::net::SocketAddr,
    path: &str,
    rounds: usize,
    body_of: &(impl Fn(usize, usize) -> String + Sync),
) -> ModeResult {
    let wall = Instant::now();
    let mut lats: Vec<f64> = Vec::with_capacity(CONCURRENCY * rounds);
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for client in 0..CONCURRENCY {
            joins.push(s.spawn(move || {
                let mut http = HttpClient::connect(addr).expect("connect");
                let mut lat = Vec::with_capacity(rounds);
                for round in 0..rounds {
                    let body = body_of(client, round);
                    let t = Instant::now();
                    let (status, resp) = http.post(path, &body).expect("post");
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    assert_eq!(status, 200, "{resp}");
                }
                lat
            }));
        }
        for j in joins {
            lats.extend(j.join().expect("client panicked"));
        }
    });
    let wall_s = wall.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    ModeResult {
        rps: lats.len() as f64 / wall_s,
        p50_ms: percentile(&lats, 50.0),
        p99_ms: percentile(&lats, 99.0),
    }
}

/// Threads carrying the concurrency sweep. Each owns `conns / THREADS`
/// keep-alive connections and drives them round-robin, so the measured
/// concurrency is *open connections* (the event loop's axis), while
/// in-flight requests stay bounded by the thread count.
const SWEEP_THREADS: usize = 16;

/// Open `conns` keep-alive connections, then run `rounds` matvec
/// requests over every one of them, bit-checking every 97th response
/// against the in-process operator.
fn keepalive_sweep(
    addr: std::net::SocketAddr,
    conns: usize,
    rounds: usize,
    n: usize,
    model: &Arc<VdtModel>,
) -> ModeResult {
    let per = (conns / SWEEP_THREADS).max(1);
    let barrier = std::sync::Barrier::new(SWEEP_THREADS);
    let wall = Instant::now();
    let mut lats: Vec<f64> = Vec::with_capacity(per * SWEEP_THREADS * rounds);
    std::thread::scope(|s| {
        let barrier = &barrier;
        let mut joins = Vec::new();
        for t in 0..SWEEP_THREADS {
            let model = model.clone();
            joins.push(s.spawn(move || {
                let mut clients: Vec<HttpClient> = (0..per)
                    .map(|i| {
                        HttpClient::connect(addr)
                            .unwrap_or_else(|e| panic!("connect {}: {e}", t * per + i))
                    })
                    .collect();
                // every connection is open before any traffic flows —
                // the sweep measures serving at full connection count
                barrier.wait();
                let mut lat = Vec::with_capacity(per * rounds);
                for round in 0..rounds {
                    for (i, http) in clients.iter_mut().enumerate() {
                        let id = t * per + i;
                        let tag = id * 10 + round;
                        let y = Matrix::from_fn(n, 1, move |r, _| {
                            (((r * 31 + tag * 7) % 19) as f32 - 9.0) * 0.1
                        });
                        let body = matrix_body("y", &y);
                        let tt = Instant::now();
                        let (status, resp) =
                            http.post("/v1/models/bench/matvec", &body).expect("post");
                        lat.push(tt.elapsed().as_secs_f64() * 1e3);
                        assert_eq!(status, 200, "conn {id}: {resp}");
                        if id % 97 == 0 {
                            let got = matrix_from_json(
                                Json::parse(&resp).expect("json").get("yhat").expect("yhat"),
                                "yhat",
                            )
                            .expect("decode");
                            assert_eq!(
                                got.data,
                                model.matvec(&y).data,
                                "conn {id} not bit-identical under {conns}-conn load"
                            );
                        }
                    }
                }
                lat
            }));
        }
        for j in joins {
            lats.extend(j.join().expect("sweep thread panicked"));
        }
    });
    let wall_s = wall.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    ModeResult {
        rps: lats.len() as f64 / wall_s,
        p50_ms: percentile(&lats, 50.0),
        p99_ms: percentile(&lats, 99.0),
    }
}

struct Stack {
    handle: CoordinatorHandle,
    server: vdt::runtime::server::ServerHandle,
}

fn spawn_stack(model: &Arc<VdtModel>, batched: bool) -> Stack {
    let handle = if batched {
        Coordinator::spawn()
    } else {
        Coordinator::spawn_with(CoordinatorConfig {
            burst_window: Duration::ZERO,
            fuse: false,
        })
    };
    handle.register("bench", model.clone());
    let cfg = ServerConfig {
        workers: CONCURRENCY + 4,
        queue_depth: CONCURRENCY * 2,
        batch_window: Duration::from_millis(1),
        max_batch: CONCURRENCY * 2,
        batching: batched,
        ..ServerConfig::default()
    };
    let server = Server::bind(handle.clone(), "127.0.0.1:0", cfg).expect("bind");
    Stack { handle, server }
}

fn main() {
    let n = env_usize("BENCH_N", 8000);
    let rounds = env_usize("BENCH_HTTP_REQS", 8);
    println!("# http_throughput: N={n}, concurrency={CONCURRENCY}, {rounds} reqs/client");

    let ds = synthetic::digit1_like(n, 1);
    let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
    m.refine_to(6 * n);
    let model = Arc::new(m);
    let d = ds.x.cols;

    let matvec_body = move |client: usize, round: usize| {
        let tag = client * 1000 + round;
        let y =
            Matrix::from_fn(n, 1, move |r, _| (((r * 31 + tag * 7) % 19) as f32 - 9.0) * 0.1);
        matrix_body("y", &y)
    };
    let matvec8_body = move |client: usize, round: usize| {
        let tag = client * 1000 + round;
        let y = Matrix::from_fn(n, 8, move |r, k| {
            (((r * 31 + k * 11 + tag * 7) % 19) as f32 - 9.0) * 0.1
        });
        matrix_body("y", &y)
    };
    let query_body = {
        let x = ds.x.clone();
        move |client: usize, round: usize| {
            let row = (client * 131 + round * 17) % x.rows;
            let q = Matrix::from_vec(x.row(row).to_vec(), 1, d);
            matrix_body("x", &q)
        }
    };

    let mut results: Vec<(String, ModeResult)> = Vec::new();
    for batched in [true, false] {
        let mode = if batched { "batched" } else { "unbatched" };
        let stack = spawn_stack(&model, batched);
        let addr = stack.server.addr();

        // correctness gate before any timing
        {
            let mut http = HttpClient::connect(addr).expect("connect");
            let y = Matrix::from_fn(n, 1, |r, _| ((r % 13) as f32 - 6.0) * 0.2);
            let (status, body) =
                http.post("/v1/models/bench/matvec", &matrix_body("y", &y)).expect("post");
            assert_eq!(status, 200, "{body}");
            let got = matrix_from_json(
                Json::parse(&body).expect("json").get("yhat").expect("yhat"),
                "yhat",
            )
            .expect("decode");
            assert_eq!(
                got.data,
                model.matvec(&y).data,
                "{mode} serving is not bit-identical to the in-process operator"
            );
            // same gate for the multi-RHS request shape
            let y8 = Matrix::from_fn(n, 8, |r, k| (((r * 7 + k * 3) % 13) as f32 - 6.0) * 0.2);
            let (status, body) =
                http.post("/v1/models/bench/matvec", &matrix_body("y", &y8)).expect("post");
            assert_eq!(status, 200, "{body}");
            let got8 = matrix_from_json(
                Json::parse(&body).expect("json").get("yhat").expect("yhat"),
                "yhat",
            )
            .expect("decode");
            assert_eq!(
                got8.data,
                model.matmul(&y8).data,
                "{mode} multi-column serving is not bit-identical to the in-process operator"
            );
        }

        // brief warmup so thread pools and scratch lanes exist
        let _ = hammer(addr, "/v1/models/bench/matvec", 2, &matvec_body);

        let mv = hammer(addr, "/v1/models/bench/matvec", rounds, &matvec_body);
        println!(
            "# {mode}/matvec: {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms",
            mv.rps, mv.p50_ms, mv.p99_ms
        );
        results.push((format!("{mode}/matvec"), mv));

        let mv8 = hammer(addr, "/v1/models/bench/matvec", rounds, &matvec8_body);
        println!(
            "# {mode}/matvec8: {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms",
            mv8.rps, mv8.p50_ms, mv8.p99_ms
        );
        results.push((format!("{mode}/matvec8"), mv8));

        let q = hammer(addr, "/v1/models/bench/query", rounds, &query_body);
        println!(
            "# {mode}/query: {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms",
            q.rps, q.p50_ms, q.p99_ms
        );
        results.push((format!("{mode}/query"), q));

        let http_stats = stack.server.stats();
        println!(
            "# {mode}: {} http requests, {} micro-batches carrying {} requests",
            http_stats.requests, http_stats.batches, http_stats.batched_requests
        );
        stack.server.shutdown();
        stack.handle.shutdown();
    }

    // ---- keep-alive concurrency sweep (event-loop axis) ----
    // default workers on purpose: the acceptance bar is 1k concurrent
    // keep-alive connections WITHOUT raising the compute pool
    let fd_budget = vdt::runtime::server::raise_fd_limit().unwrap_or(1024);
    let want_conns = env_usize("BENCH_HTTP_CONNS", 1024);
    let conns = want_conns.min(((fd_budget.saturating_sub(128)) / 2) as usize).max(64);
    if conns < want_conns {
        println!("# sweep clamped to {conns} connections by the fd limit ({fd_budget})");
    }
    {
        let handle = Coordinator::spawn();
        handle.register("bench", model.clone());
        let server = Server::bind(
            handle.clone(),
            "127.0.0.1:0",
            ServerConfig {
                max_conns: conns + 64,
                batch_window: Duration::from_millis(1),
                max_batch: 128,
                ..ServerConfig::default()
            },
        )
        .expect("bind sweep server");
        let sweep_rounds = env_usize("BENCH_HTTP_SWEEP_REQS", 3);
        let r = keepalive_sweep(server.addr(), conns, sweep_rounds, n, &model);
        println!(
            "# batched/matvec@c{conns}: {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms",
            r.rps, r.p50_ms, r.p99_ms
        );
        let stats = server.stats();
        assert_eq!(stats.errors, 0, "sweep produced protocol errors");
        assert_eq!(stats.rejected, 0, "sweep was rejected below max_conns");
        results.push((format!("batched/matvec@c{conns}"), r));

        // ---- observability scrape cost ----
        // against this fully-populated registry (per-endpoint latency
        // histograms with real samples, batcher instruments, stage
        // timers): /metrics renders the whole exposition per GET, /stats
        // snapshots every histogram and interpolates three quantiles
        let mut http = HttpClient::connect(server.addr()).expect("connect scrape client");
        for (path, name) in [("/metrics", "obs/metrics_scrape"), ("/stats", "obs/stats_scrape")]
        {
            let scrapes = env_usize("BENCH_HTTP_SCRAPES", 200);
            let mut lat = Vec::with_capacity(scrapes);
            for i in 0..scrapes {
                let t = Instant::now();
                let (status, body) = http.get(path).expect("scrape");
                lat.push(t.elapsed().as_secs_f64() * 1e3);
                assert_eq!(status, 200, "{body}");
                if i == 0 && path == "/metrics" {
                    assert!(
                        body.contains("vdt_http_requests_total"),
                        "scrape body lost the core families:\n{body}"
                    );
                }
            }
            lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
            let total_s: f64 = lat.iter().sum::<f64>() / 1e3;
            let r = ModeResult {
                rps: lat.len() as f64 / total_s,
                p50_ms: percentile(&lat, 50.0),
                p99_ms: percentile(&lat, 99.0),
            };
            println!(
                "# {name}: {:.0} scrapes/s, p50 {:.3} ms, p99 {:.3} ms",
                r.rps, r.p50_ms, r.p99_ms
            );
            results.push((name.to_string(), r));
        }
        server.shutdown();
        handle.shutdown();
    }

    // ---- raw instrument overhead ----
    // the always-on per-request cost: one histogram observation (shard
    // pick + bucket search + three relaxed atomics). Recorded as the
    // wall time of a 4M-observation hot loop so the regression gate
    // catches an instrumentation slowdown directly.
    {
        use vdt::core::obs::Registry;
        let reg = Registry::new();
        let h = reg.histogram("bench_observe_seconds", "observe-loop cost", &[]);
        const OBS: usize = 4_000_000;
        // spread observations across the full bucket range
        let t = Instant::now();
        for i in 0..OBS {
            h.observe((i % 997) as f64 * 1e-5);
        }
        let ms = t.elapsed().as_secs_f64() * 1e3;
        assert_eq!(h.count(), OBS as u64);
        println!("# obs/observe_x4m: {ms:.1} ms ({:.1} ns/observe)", ms * 1e6 / OBS as f64);
        results.push((
            "obs/observe_x4m".to_string(),
            ModeResult { rps: OBS as f64 / (ms / 1e3), p50_ms: ms, p99_ms: ms },
        ));
    }

    let get = |k: &str| results.iter().find(|(name, _)| name == k).expect("mode ran").1;
    let mv_speedup = get("batched/matvec").rps / get("unbatched/matvec").rps;
    let mv8_speedup = get("batched/matvec8").rps / get("unbatched/matvec8").rps;
    let q_speedup = get("batched/query").rps / get("unbatched/query").rps;
    println!(
        "# speedup batched/unbatched: matvec {mv_speedup:.2}x, matvec8 {mv8_speedup:.2}x, query {q_speedup:.2}x"
    );

    // ---- emit BENCH_http.json ----
    // schema matches benches/check_regression.py: entries under "paths",
    // keyed by "path", with gated timings in *_ms fields (rps is recorded
    // but not gated — the p50/p99 latencies are)
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"http_throughput\",\n  \"n\": {n},\n  \"concurrency\": {CONCURRENCY},\n  \"requests_per_client\": {rounds},\n  \"paths\": [\n"
    ));
    for (i, (name, r)) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"path\": \"{name}\", \"rps\": {:.1}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}}}{}\n",
            r.rps,
            r.p50_ms,
            r.p99_ms,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"matvec_batching_speedup\": {mv_speedup:.3},\n  \"query_batching_speedup\": {q_speedup:.3}\n}}\n"
    ));
    if let Err(e) = std::fs::write("BENCH_http.json", &json) {
        eprintln!("warn: could not write BENCH_http.json: {e}");
    } else {
        println!(
            "# wrote BENCH_http.json (batched {mv_speedup:.1}x matvec, {q_speedup:.1}x query vs unbatched)"
        );
    }
}
