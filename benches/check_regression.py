#!/usr/bin/env python3
"""Regression gate for the BENCH_*.json perf records.

Usage: check_regression.py <current.json> <baseline.json> [tolerance]

Both files are the JSON emitted by `benches/parallel_scaling.rs`
(`serial_ms` / `parallel_ms` per path) or `benches/serve_warmstart.rs`
(`ms` per path). Paths are matched by their `path` key; every timing
field (`ms` or `*_ms`) must satisfy

    current <= baseline * (1 + tolerance)

with tolerance defaulting to 0.25 (the CI bench job's >25% gate). A
baseline path missing from the current run fails (a rename must not
silently disable its gate), as does a problem-size (n) mismatch; paths
new in the current run are only reported (bench sets may grow), and a
shrinking timing never fails.

Exit status: 0 = within tolerance, 1 = regression (or unreadable input).
"""

import json
import sys


def timing_fields(entry):
    return {
        k: v
        for k, v in entry.items()
        if (k == "ms" or k.endswith("_ms")) and isinstance(v, (int, float))
    }


def main(argv):
    if len(argv) < 3:
        print(__doc__.strip())
        return 1
    tol = float(argv[3]) if len(argv) > 3 else 0.25
    try:
        with open(argv[1]) as f:
            cur = json.load(f)
        with open(argv[2]) as f:
            base = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read inputs: {e}")
        return 1

    cur_paths = {p["path"]: p for p in cur.get("paths", [])}
    base_paths = {p["path"]: p for p in base.get("paths", [])}
    if cur.get("n") != base.get("n"):
        # a different problem size invalidates every ratio below — fail
        # rather than bless an apples-to-oranges comparison
        print(
            f"FAIL: size mismatch (current n={cur.get('n')}, baseline "
            f"n={base.get('n')}) — re-record the baseline at the CI size"
        )
        return 1

    failed = []
    for name, b_entry in sorted(base_paths.items()):
        c_entry = cur_paths.get(name)
        if c_entry is None:
            # a renamed/dropped path must not silently disable its gate
            print(f"  {name}: missing from current run (baseline has it) REGRESSION")
            failed.append(f"{name} (missing)")
            continue
        b_fields = timing_fields(b_entry)
        for field, b_val in sorted(b_fields.items()):
            c_val = timing_fields(c_entry).get(field)
            if c_val is None or b_val <= 0:
                continue
            ratio = c_val / b_val
            verdict = "OK" if ratio <= 1.0 + tol else "REGRESSION"
            print(
                f"  {name}.{field}: {c_val:.3f} ms vs baseline {b_val:.3f} ms "
                f"({ratio:.2f}x) {verdict}"
            )
            if verdict != "OK":
                failed.append(f"{name}.{field}")
    for name in sorted(set(cur_paths) - set(base_paths)):
        print(f"  {name}: new path (no baseline)")

    if failed:
        print(f"FAIL: {len(failed)} timing(s) regressed >{tol:.0%}: {', '.join(failed)}")
        return 1
    print(f"PASS: no timing regressed more than {tol:.0%} vs baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
