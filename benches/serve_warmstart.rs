//! Cold-fit vs snapshot warm-start latency at serving sizes — the number
//! the `runtime::snapshot` subsystem exists to move. Measures:
//!
//! - `cold_fit`: build + refine from raw points (what `vdt serve` did on
//!   every process start before snapshots),
//! - `snapshot_load`: `VdtModel::load` from a snapshot file (the warm
//!   start), including full checksum/structure validation,
//! - `first_matvec`: first Algorithm-1 sweep on a freshly loaded model
//!   (scratch pool cold), i.e. load-to-first-response tail,
//! - `steady_matvec`: the same sweep with warm scratch, for reference.
//!
//! Emits `BENCH_serve.json` (consumed by the CI bench job alongside
//! `BENCH_parallel.json`). `BENCH_N` overrides the default N=16k for
//! smoke runs. The bench also asserts the loaded model's matvec is
//! bit-identical to the fitted model's — a perf run that serves wrong
//! numbers must fail loudly.

use vdt::core::bench::Runner;
use vdt::data::synthetic;
use vdt::vdt::{VdtConfig, VdtModel};
use vdt::Matrix;

fn env_n(default: usize) -> usize {
    std::env::var("BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n = env_n(16_000);
    let k = 6usize;
    let mut r = Runner::from_args();
    r.budget_secs = 1.0;
    r.max_iters = 5;
    println!("# serve_warmstart: N={n}, refine target {k}N");

    // the snapshot source: one reference fit, saved to a temp file
    let ds = synthetic::digit1_like(n, 1);
    let mut fitted = VdtModel::build(&ds.x, &VdtConfig::default());
    fitted.refine_to(k * n);
    let blocks = fitted.num_blocks();
    let path = std::env::temp_dir().join(format!("vdt_serve_warmstart_{n}.vdt"));
    fitted.save(&path, &ds.name).expect("save snapshot");
    let snapshot_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!("# snapshot: {} blocks, {:.1} KiB", blocks, snapshot_bytes as f64 / 1024.0);

    // correctness gate: warm start must serve the fit's exact bits
    let y = Matrix::from_fn(n, 4, |row, c| (((row * 31 + c * 17) % 23) as f32 - 11.0) * 0.25);
    let loaded = VdtModel::load(&path).expect("load snapshot");
    assert_eq!(
        fitted.matvec(&y).data,
        loaded.matvec(&y).data,
        "snapshot warm start diverged from the in-process fit"
    );

    r.bench(&format!("serve/cold_fit/N={n}"), || {
        let mut m = VdtModel::build(&ds.x, &VdtConfig::default());
        m.refine_to(k * n);
        std::hint::black_box(m.num_blocks());
    });
    r.bench(&format!("serve/snapshot_load/N={n}"), || {
        std::hint::black_box(VdtModel::load(&path).expect("load snapshot"));
    });
    r.bench_with_setup(
        &format!("serve/first_matvec/N={n}"),
        || VdtModel::load(&path).expect("load snapshot"),
        |m| std::hint::black_box(m.matvec(&y)).rows,
    );
    r.bench(&format!("serve/steady_matvec/N={n}"), || {
        std::hint::black_box(loaded.matvec(&y));
    });
    let _ = std::fs::remove_file(&path);

    // ---- emit BENCH_serve.json ----
    let keys = ["cold_fit", "snapshot_load", "first_matvec", "steady_matvec"];
    let names: Vec<String> = keys.iter().map(|key| format!("serve/{key}/N={n}")).collect();
    if names.iter().any(|name| r.mean_of(name).is_none()) {
        println!("# filtered run: skipping BENCH_serve.json (needs all paths)");
        return;
    }
    let cold = r.mean_of(&names[0]).expect("checked above");
    let warm = r.mean_of(&names[1]).expect("checked above");
    let speedup = cold / warm;
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"bench\": \"serve_warmstart\",\n  \"n\": {n},\n"));
    json.push_str(&format!(
        "  \"blocks\": {blocks},\n  \"snapshot_bytes\": {snapshot_bytes},\n  \"paths\": [\n"
    ));
    for (i, (key, name)) in keys.iter().zip(names.iter()).enumerate() {
        let ms = r.mean_of(name).expect("checked above");
        json.push_str(&format!(
            "    {{\"path\": \"{key}\", \"ms\": {ms:.3}}}{}\n",
            if i + 1 < keys.len() { "," } else { "" }
        ));
        println!("# {key}: {ms:.1} ms");
    }
    json.push_str(&format!("  ],\n  \"warmstart_speedup\": {speedup:.3}\n}}\n"));
    if let Err(e) = std::fs::write("BENCH_serve.json", &json) {
        eprintln!("warn: could not write BENCH_serve.json: {e}");
    } else {
        println!("# wrote BENCH_serve.json (warm start {speedup:.1}x faster than cold fit)");
    }
}
