//! Kernel throughput — `vdt::kernels` on the VDT operator at BENCH_N
//! (default 4000, |B| = 6N): deterministic power kernels (diffusion /
//! PPR) per column width, the GRF walk sampler serial vs parallel, and
//! commute-distance batches. Emits `BENCH_kernels.json` for the CI bench
//! gate. Bit-parity is asserted before timing: fused power columns equal
//! stacked single-column runs, and the parallel GRF sampler equals
//! serial.

use vdt::core::bench::Runner;
use vdt::core::par;
use vdt::data::synthetic;
use vdt::kernels::{self, GrfConfig, PowerKernel};
use vdt::vdt::{VdtConfig, VdtModel};
use vdt::Matrix;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let mut r = Runner::from_args();
    let n = env_usize("BENCH_N", 4000);
    let widths = [4usize, 16];

    println!("# kernel throughput (N={n}, |B|=6N)");
    let ds = synthetic::gaussian_mixture(n, 32, 8, 2, 2.2, 2, "kernels_bench");
    let mut model = VdtModel::build(&ds.x, &VdtConfig::default());
    model.refine_to(6 * n);

    // ---- deterministic power kernels ----
    let diffusion = PowerKernel::Diffusion { steps: 10 };
    let ppr = PowerKernel::Ppr { alpha: 0.15, steps: 10 };
    for &c in &widths {
        let y0 = Matrix::from_fn(n, c, |row, k| if row % (k + 3) == 0 { 1.0 } else { 0.0 });

        // parity gate: the fused multi-column run must be bit-identical
        // to stacked single columns before its timing means anything
        let fused = kernels::power(&model, ppr, &y0);
        for k in 0..c {
            let col = Matrix::from_fn(n, 1, |row, _| y0.get(row, k));
            let solo = kernels::power(&model, ppr, &col);
            for row in 0..n {
                assert_eq!(
                    solo.get(row, 0).to_bits(),
                    fused.get(row, k).to_bits(),
                    "C={c} col={k}: fused power run diverged from per-column"
                );
            }
        }

        let mut out = Matrix::zeros(n, c);
        let mut scratch = Matrix::zeros(n, c);
        for (label, kernel) in [("diffusion", diffusion), ("ppr", ppr)] {
            r.bench(&format!("kernels/{label}/C={c}"), || {
                kernels::power_into(&model, kernel, &y0, &mut out, &mut scratch);
                std::hint::black_box(&out);
            });
        }
    }

    // ---- GRF walk sampling, serial vs parallel ----
    let starts: Vec<usize> = (0..64).map(|i| (i * 97) % n).collect();
    let cfg = GrfConfig { walks: 32, seed: 11, ..GrfConfig::default() };
    let hw = par::max_threads();
    let parallel = kernels::grf_rows(&model, &starts, &cfg).unwrap();
    {
        let prev = par::set_max_threads(1);
        let serial = kernels::grf_rows(&model, &starts, &cfg).unwrap();
        par::set_max_threads(prev);
        assert_eq!(parallel.data, serial.data, "par GRF is not bit-exact vs serial");
    }
    for (label, threads) in [("serial", 1usize), ("threads", hw)] {
        let prev = par::set_max_threads(threads);
        r.bench(&format!("kernels/grf_64rows/{label}"), || {
            std::hint::black_box(kernels::grf_rows(&model, &starts, &cfg).unwrap());
        });
        par::set_max_threads(prev);
    }
    if let (Some(s), Some(t)) = (
        r.mean_of("kernels/grf_64rows/serial"),
        r.mean_of("kernels/grf_64rows/threads"),
    ) {
        println!("# GRF parallel speedup at 64 rows: {:.2}x ({hw} threads)", s / t);
    }

    // ---- commute-distance batch ----
    let pairs: Vec<(usize, usize)> = (0..32).map(|i| ((i * 53) % n, (i * 71 + 9) % n)).collect();
    r.bench("kernels/commute_32pairs", || {
        std::hint::black_box(kernels::commute_times(&model, &pairs, &cfg).unwrap());
    });

    // ---- emit BENCH_kernels.json ----
    // schema matches benches/check_regression.py: entries under "paths",
    // keyed by "path", gated timing in "ms"
    let mut names: Vec<String> = Vec::new();
    for &c in &widths {
        names.push(format!("kernels/diffusion/C={c}"));
        names.push(format!("kernels/ppr/C={c}"));
    }
    names.push("kernels/grf_64rows/serial".to_string());
    names.push("kernels/grf_64rows/threads".to_string());
    names.push("kernels/commute_32pairs".to_string());
    let entries: Vec<(String, f64)> =
        names.into_iter().filter_map(|name| r.mean_of(&name).map(|ms| (name, ms))).collect();
    if entries.is_empty() {
        println!("# BENCH_kernels.json skipped (all sections filtered out)");
        return;
    }
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"kernel_throughput\",\n  \"n\": {n},\n  \"threads\": {hw},\n  \"paths\": [\n"
    ));
    for (i, (name, ms)) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"path\": \"{name}\", \"ms\": {ms:.3}}}{}\n",
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_kernels.json", &json) {
        eprintln!("warn: could not write BENCH_kernels.json: {e}");
    } else {
        println!("# wrote BENCH_kernels.json ({} timings)", entries.len());
    }
}
