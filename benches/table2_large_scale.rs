//! Table 2 — very-large-scale construction and propagation, bench-sized.
//! (The headline alpha_n=100k/ocr_n=50k runs live in `vdt exp table2` and
//! EXPERIMENTS.md; timing loops at those sizes would take hours, so this
//! harness measures the same code path at 20k/10k.)

use vdt::core::bench::Runner;
use vdt::data::synthetic;
use vdt::labelprop::{self, LpConfig};
use vdt::vdt::{VdtConfig, VdtModel};

fn main() {
    let mut r = Runner::from_args();
    r.max_iters = 10;
    for (name, ds) in [
        ("alpha_like_20k", synthetic::alpha_like(20_000, 1)),
        ("ocr_like_10k", synthetic::ocr_like(10_000, 1)),
    ] {
        r.bench(&format!("table2/construction/{name}"), || {
            std::hint::black_box(VdtModel::build(&ds.x, &VdtConfig::default()));
        });
        let model = VdtModel::build(&ds.x, &VdtConfig::default());
        let labeled = labelprop::choose_labeled(&ds.labels, ds.n_classes, ds.n() / 10, 2);
        let y0 = labelprop::seed_matrix(&ds.labels, &labeled, ds.n_classes);
        // one 10-step propagation chunk (paper's T=500 = 50 of these)
        r.bench(&format!("table2/propagate_10_steps/{name}"), || {
            std::hint::black_box(labelprop::propagate(
                &model,
                &y0,
                &LpConfig { alpha: 0.01, steps: 10 },
            ));
        });
    }
}
