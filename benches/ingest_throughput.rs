//! Online-ingest throughput — `vdt::vdt::ingest` + the epoch commit path
//! at BENCH_N (default 4000, |B| = 6N): points/second absorbed into a
//! shadow copy at several batch sizes, the snapshot-clone cost a first
//! ingest of an epoch pays, and commit + first-matvec-after-commit
//! latency. Emits `BENCH_ingest.json` for the CI bench gate.
//!
//! Correctness is asserted before timing: the committed model's matvec
//! of the all-ones vector stays row-stochastic, and its snapshot
//! round-trips bit-exactly.

use vdt::core::bench::Runner;
use vdt::data::synthetic;
use vdt::runtime::Snapshot;
use vdt::vdt::ingest::{IngestConfig, ShadowIngest};
use vdt::vdt::{VdtConfig, VdtModel};
use vdt::Matrix;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Distinct rows near the data manifold, unique per (batch, row).
fn rows_near(m: &VdtModel, k: usize, tag: usize) -> Matrix {
    let d = m.tree.d;
    Matrix::from_fn(k, d, |r, c| {
        let base = m.tree.s1[(((r + tag * 7) * 13) % m.tree.n) * d + c];
        base + 1e-3 * (1.0 + r as f32 + c as f32) + 1e-5 * (tag as f32 + 1.0)
    })
}

fn main() {
    let mut r = Runner::from_args();
    let n = env_usize("BENCH_N", 4000);
    let batches = [1usize, 16, 128];

    println!("# ingest throughput (N={n}, |B|=6N)");
    let ds = synthetic::gaussian_mixture(n, 16, 4, 2, 2.2, 3, "ingest_bench");
    let mut model = VdtModel::build(&ds.x, &VdtConfig::default());
    model.refine_to(6 * n);
    let model = model;

    // correctness gate before any timing: ingest + commit must keep the
    // operator row-stochastic and v2-snapshot-stable
    {
        let mut sh = ShadowIngest::new(clone_via_snapshot(&model), IngestConfig::default());
        sh.ingest_rows(&rows_near(&model, 32, 0)).unwrap();
        let committed = sh.into_model();
        committed.partition.validate(&committed.tree).unwrap();
        let ones = Matrix::from_fn(committed.n(), 1, |_, _| 1.0);
        for (i, &v) in committed.matvec(&ones).data.iter().enumerate() {
            assert!((v - 1.0).abs() < 1e-4, "row {i} sum {v} after ingest");
        }
        let bytes = committed.to_snapshot("bench").encode().unwrap();
        let back = VdtModel::from_snapshot(Snapshot::decode(&bytes).unwrap()).unwrap();
        assert_eq!(
            committed.matvec(&ones).data,
            back.matvec(&ones).data,
            "snapshot roundtrip drifted"
        );
    }

    // ---- shadow clone (the first ingest of an epoch pays this once) ----
    r.bench("ingest/shadow_clone", || {
        std::hint::black_box(clone_via_snapshot(&model));
    });

    // ---- ingest throughput per batch size ----
    for &k in &batches {
        let mut tag = 1usize;
        let mut shadow = Some(ShadowIngest::new(clone_via_snapshot(&model), IngestConfig::default()));
        r.bench(&format!("ingest/rows/k={k}"), || {
            // recycle the shadow before it grows far beyond N (keeps the
            // per-iteration work comparable across the run)
            let grown = shadow.as_ref().map_or(0, |s| s.inserted()) as usize;
            if grown > n / 4 {
                shadow = Some(ShadowIngest::new(
                    clone_via_snapshot(&model),
                    IngestConfig::default(),
                ));
            }
            let sh = shadow.as_mut().expect("shadow present");
            let rows = rows_near(sh.model(), k, tag);
            tag += 1;
            sh.ingest_rows(&rows).expect("bench rows are valid");
        });
        if let Some(ms) = r.mean_of(&format!("ingest/rows/k={k}")) {
            println!("#   k={k}: {:.0} points/s", k as f64 / (ms / 1e3));
        }
    }

    // ---- commit + first serve after the swap ----
    let mut sh = ShadowIngest::new(clone_via_snapshot(&model), IngestConfig::default());
    sh.ingest_rows(&rows_near(&model, 64, 900)).unwrap();
    let committed = sh.into_model();
    let y = Matrix::from_fn(committed.n(), 4, |row, c| (((row * 5 + c) % 9) as f32 - 4.0) * 0.2);
    let mut out = Matrix::zeros(committed.n(), 4);
    r.bench("ingest/first_matvec_after_commit", || {
        committed.matvec_into(&y, &mut out);
        std::hint::black_box(&out);
    });

    // ---- emit BENCH_ingest.json ----
    // schema matches benches/check_regression.py: entries under "paths",
    // keyed by "path", gated timing in "ms"
    let mut names = vec!["ingest/shadow_clone".to_string()];
    for &k in &batches {
        names.push(format!("ingest/rows/k={k}"));
    }
    names.push("ingest/first_matvec_after_commit".to_string());
    let entries: Vec<(String, f64)> =
        names.into_iter().filter_map(|name| r.mean_of(&name).map(|ms| (name, ms))).collect();
    if entries.is_empty() {
        println!("# BENCH_ingest.json skipped (all sections filtered out)");
        return;
    }
    let threads = vdt::core::par::max_threads();
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"bench\": \"ingest_throughput\",\n  \"n\": {n},\n  \"threads\": {threads},\n  \"paths\": [\n"
    ));
    for (i, (name, ms)) in entries.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"path\": \"{name}\", \"ms\": {ms:.3}}}{}\n",
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_ingest.json", &json) {
        eprintln!("warn: could not write BENCH_ingest.json: {e}");
    } else {
        println!("# wrote BENCH_ingest.json ({} timings)", entries.len());
    }
}

/// The epoch ledger's shadow-clone path: encode → decode → rebuild
/// (VdtModel deliberately has no `Clone`).
fn clone_via_snapshot(m: &VdtModel) -> VdtModel {
    let bytes = m.to_snapshot("bench").encode().expect("encode");
    VdtModel::from_snapshot(Snapshot::decode(&bytes).expect("decode")).expect("rebuild")
}
