//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! This build is offline (no registry access), so the subset of anyhow the
//! repository actually uses is vendored here: [`Error`], [`Result`], the
//! [`anyhow!`] macro, and the [`Context`] extension trait for `Result` and
//! `Option`. Errors carry a message plus an optional chain of context
//! strings; `{:#}`/source-chain walking beyond that is out of scope.

use std::fmt;

/// A type-erased error: the originating message plus context frames, most
/// recent first (matching anyhow's Display of the outermost context).
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap an existing error value, preserving its Display output.
    pub fn new<E: fmt::Display>(e: E) -> Error {
        Error { msg: e.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format args, `anyhow::anyhow!`-style.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Construct-and-return, `anyhow::bail!`-style.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to errors, anyhow-style.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> Result<u32> {
        let n: u32 = v.parse().context("not a number")?;
        Ok(n)
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("12").unwrap(), 12);
        let e = parse("x").unwrap_err().to_string();
        assert!(e.starts_with("not a number"), "{e}");
    }

    #[test]
    fn option_context() {
        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }
}
