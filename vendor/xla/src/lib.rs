//! API-compatible **stub** of the `xla` (PJRT) bindings.
//!
//! The real crate links `xla_extension`'s native libraries, which are not
//! available in this offline build environment. This stub exposes the same
//! surface [`vdt::runtime`] consumes so the crate compiles everywhere;
//! [`PjRtClient::cpu`] returns an error, which the runtime and its tests
//! already treat as "XLA unavailable — skip" (see
//! `rust/tests/xla_roundtrip.rs`). Swap this path dependency for the real
//! crate to enable the AOT artifact path; no `vdt` source changes needed.

// The stub's zero-sized private fields and host-only `Literal` storage are
// intentionally inert outside `cfg(test)` builds.
#![allow(dead_code)]

use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' string-ish errors.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("XLA runtime unavailable: built against the in-tree stub (vendor/xla)".to_string())
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}

/// Host-side tensor value.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    /// 0-D scalar literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: vec![v], dims: vec![] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// First element of a 1-tuple result.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer returned by execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle. In the stub, construction always fails — callers
/// (e.g. `vdt::runtime::Runtime::load`) surface that as "run with the real
/// xla crate / `make artifacts` for the XLA path".
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_shape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::scalar(0.5);
        assert_eq!(s.data, vec![0.5]);
        assert!(s.dims.is_empty());
    }
}
